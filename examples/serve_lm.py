"""Serving driver (thin wrapper over repro.launch.serve):
clients -> thin-admission batcher -> continuous-batching engine server,
with latency percentiles. ``--mode lockstep`` runs the batch-at-a-time
baseline instead.

``--replicas N --routers M`` serves through the replicated fabric
instead: engine replicas register with a discovery Registry and
heartbeat load reports; routers dispatch each request to the
least-loaded replica and fail over when one dies. ``--kill-after N``
is the failover demo — one replica is killed after N requests have
been served (deterministically mid-run) and traffic keeps flowing on
its siblings:

    PYTHONPATH=src python examples/serve_lm.py --clients 3 --requests 4
    PYTHONPATH=src python examples/serve_lm.py --replicas 2 --routers 1 \\
        --requests 6 --kill-after 4
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
