"""Batched-serving driver (thin wrapper over repro.launch.serve):
clients -> batcher -> SPMD model server, with latency percentiles.

    PYTHONPATH=src python examples/serve_lm.py --clients 3 --requests 4
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
