"""Serving driver (thin wrapper over repro.launch.serve):
clients -> thin-admission batcher -> continuous-batching engine server,
with latency percentiles. ``--mode lockstep`` runs the batch-at-a-time
baseline instead.

    PYTHONPATH=src python examples/serve_lm.py --clients 3 --requests 4
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
