"""Serving driver (thin wrapper over repro.launch.serve):
clients -> thin-admission batcher -> continuous-batching engine server,
with latency percentiles. ``--mode lockstep`` runs the batch-at-a-time
baseline instead.

``--replicas N --routers M`` serves through the replicated fabric
instead: engine replicas register with a discovery Registry and
heartbeat load reports; routers dispatch each request to the
least-loaded replica and fail over when one dies. ``--kill-after N``
is the failover demo — one replica is killed after N requests have
been served (deterministically mid-run) and traffic keeps flowing on
its siblings. ``--rollout-after N`` is the zero-downtime rollout demo:
v0 and v1 are published into a versioned model store (``--store DIR``,
tempdir by default) and after N served requests a RolloutController
rolls the fleet v0 -> v1 one replica at a time (drain, hot-swap between
decode windows, health probe, canary) while requests keep completing:

    PYTHONPATH=src python examples/serve_lm.py --clients 3 --requests 4
    PYTHONPATH=src python examples/serve_lm.py --replicas 2 --routers 1 \\
        --requests 6 --kill-after 4
    PYTHONPATH=src python examples/serve_lm.py --replicas 2 --routers 1 \\
        --requests 8 --rollout-after 2
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
