"""End-to-end LM training driver (thin wrapper over repro.launch.train).

Default: a tiny LM for 200 steps on CPU in a few minutes. The same program
scales: ``--preset lm100m`` is the ~100M-parameter configuration, and any
assigned architecture runs via ``--arch <id> --reduced``.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset lm100m --steps 300
"""

from repro.launch.train import main

if __name__ == "__main__":
    main()
