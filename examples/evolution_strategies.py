"""Evolution strategies (paper §5.3, Listings 6/10) with straggler
mitigation.

An Evolver holds a Gaussian search distribution over the parameters of a
small JAX policy; Evaluators score samples in parallel via courier
``.futures`` (exactly the paper's pattern). Beyond the paper: the fan-out
uses ``lp.hedged_map`` — a generation completes on a quorum of evaluators,
so one slow/hung evaluator can't stall the loop (the 1000-node concern).

    PYTHONPATH=src python examples/evolution_strategies.py --generations 30
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as lp


def fitness_fn(params: np.ndarray) -> float:
    """Negative quadratic bowl around a hidden optimum (JAX-evaluated)."""
    target = jnp.arange(params.shape[0], dtype=jnp.float32) / 10.0
    x = jnp.asarray(params)
    return float(-jnp.sum((x - target) ** 2))


class Evaluator:
    def evaluate(self, params):
        return fitness_fn(np.asarray(params, np.float32))


class Evolver:
    def __init__(self, evaluators, dim=16, generations=30, sigma=0.3,
                 lr=0.2, quorum_frac=0.75):
        self._evaluators = evaluators
        self._dim = dim
        self._generations = generations
        self._sigma = sigma
        self._lr = lr
        self._quorum = max(2, int(quorum_frac * len(evaluators)))

    def run(self):
        rng = np.random.default_rng(0)
        mu = np.zeros(self._dim, np.float32)
        for g in range(self._generations):
            eps = rng.standard_normal((len(self._evaluators), self._dim))
            samples = mu + self._sigma * eps.astype(np.float32)
            calls = [
                (lambda ev=ev, s=s: ev.futures.evaluate(s))
                for ev, s in zip(self._evaluators, samples)]
            # Hedged fan-out: finish on a quorum, re-issue stragglers.
            fits = lp.hedged_map(calls, hedge_after_s=1.0,
                                 quorum=self._quorum, timeout_s=30.0)
            got = [(f, e) for f, e in zip(fits, eps) if f is not None]
            fs = np.array([f for f, _ in got], np.float32)
            es = np.stack([e for _, e in got]).astype(np.float32)
            adv = (fs - fs.mean()) / (fs.std() + 1e-8)
            grad = (adv[:, None] * es).mean(0) / self._sigma
            mu = mu + self._lr * self._sigma * grad
            if g % 5 == 0 or g == self._generations - 1:
                print(f"gen {g:3d}: mean fitness {fs.mean():8.4f} "
                      f"({len(got)}/{len(self._evaluators)} evaluators)")
        print(f"final fitness at mean: {fitness_fn(mu):.4f}")
        lp.stop_program()


def build(num_evaluators=6, generations=30) -> lp.Program:
    p = lp.Program("es")
    with p.group("evaluator"):
        evaluators = [p.add_node(lp.CourierNode(Evaluator))
                      for _ in range(num_evaluators)]
    with p.group("evolver"):
        p.add_node(lp.CourierNode(Evolver, evaluators,
                                  generations=generations))
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--evaluators", type=int, default=6)
    ap.add_argument("--generations", type=int, default=30)
    args = ap.parse_args()
    lp.launch_and_wait(build(args.evaluators, args.generations),
                       timeout_s=300)


if __name__ == "__main__":
    main()
