"""MapReduce word count (paper §5.2, Listings 5/9).

One WordMapper node per input file, hash-partitioned over CountReducer
nodes; reducers append their counts to the output file when the last
mapper finishes.

    PYTHONPATH=src python examples/mapreduce.py
"""

import argparse
import os
import tempfile
import threading

from repro import core as lp


class WordMapper:
    def __init__(self, infile_path, reducers):
        self._infile_path = infile_path
        self._reducers = reducers

    def run(self):
        for reducer in self._reducers:
            reducer.mapper_begin()
        with open(self._infile_path) as f:
            for line in f:
                for word in line.split():
                    self._send_word(word)
        for reducer in self._reducers:
            reducer.mapper_done()

    def _send_word(self, word):
        n = len(self._reducers)
        idx = hash(word) % n
        self._reducers[idx].reduce(word, 1)


class CountReducer:
    def __init__(self, outfile_path, num_mappers):
        self._remaining = num_mappers
        self._counter = {}
        self._lock = threading.Lock()
        self._outfile_path = outfile_path

    def reduce(self, key, value):
        with self._lock:
            self._counter[key] = self._counter.get(key, 0) + value

    def mapper_begin(self):
        pass

    def mapper_done(self):
        # Flush exactly once, when the LAST mapper reports done. (The
        # paper's sketch decrements an "active" counter, which can flush
        # early if a fast mapper finishes before a slow one begins.)
        with self._lock:
            self._remaining -= 1
            if self._remaining == 0:
                self._done()

    def _done(self):
        with open(self._outfile_path, "a") as f:
            for key, count in sorted(self._counter.items()):
                f.write(f"{key} {count}\n")


class Waiter:
    """Stops the program when every reducer has flushed."""

    def __init__(self, reducers, out_path, expected_total):
        self._reducers = reducers
        self._out = out_path
        self._expected = expected_total

    def run(self):
        ctx = lp.get_current_context()
        while not ctx.should_stop:
            if os.path.exists(self._out):
                with open(self._out) as f:
                    total = sum(int(l.split()[1]) for l in f if l.strip())
                if total >= self._expected:
                    print(f"word total: {total} (expected {self._expected})")
                    lp.stop_program()
                    return
            ctx.wait_for_stop(0.05)


def build(in_paths, out_path, expected_total, num_reducers=3) -> lp.Program:
    p = lp.Program("mapreduce")
    reducers = []
    with p.group("reducer"):
        for _ in range(num_reducers):
            reducers.append(p.add_node(lp.CourierNode(
                CountReducer, out_path, len(in_paths))))
    with p.group("mapper"):
        for path in in_paths:
            p.add_node(lp.CourierNode(WordMapper, path, reducers))
    p.add_node(lp.CourierNode(Waiter, reducers, out_path, expected_total))
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--files", nargs="*", default=None)
    args = ap.parse_args()

    tmp = None
    if args.files:
        in_paths = args.files
        expected = None
    else:
        tmp = tempfile.mkdtemp()
        texts = ["the quick brown fox jumps over the lazy dog\n" * 20,
                 "pack my box with five dozen liquor jugs\n" * 30]
        in_paths = []
        expected = sum(len(t.split()) for t in texts)
        for i, t in enumerate(texts):
            path = os.path.join(tmp, f"in{i}.txt")
            with open(path, "w") as f:
                f.write(t)
            in_paths.append(path)

    out_path = os.path.join(tmp or ".", "wordcount.txt")
    if os.path.exists(out_path):
        os.remove(out_path)
    program = build(in_paths, out_path, expected or 1)
    lp.launch_and_wait(program, timeout_s=60)
    with open(out_path) as f:
        lines = f.readlines()
    print(f"{len(lines)} distinct words -> {out_path}")


if __name__ == "__main__":
    main()
