"""Parameter server (paper §5.1, Listings 3/4, Figure 2).

Three topologies over the same services, selected by --mode:
  single      one server, N requesters (Listing 3)
  replicated  servers replicated behind the Registry; requesters resolve
              by role and fail over (Listing 4 left, fabric edition)
  cached      one server behind a CacherNode (Listing 4 right)

The replicated topology rides the discovery fabric: each server
heartbeats its endpoint + served count into the Registry; requesters
resolve a server by role (partitioned by requester index), and on an RPC
failure report it (report_failure -> eviction) and re-resolve. That is
what --kill-after demonstrates: one server dies mid-run, its requesters
fail over to a sibling, total QPS dips but the run completes.

    PYTHONPATH=src python examples/parameter_server.py --mode cached \
        --requesters 8 --seconds 2
    PYTHONPATH=src python examples/parameter_server.py --mode replicated \
        --requesters 8 --seconds 2 --kill-after 0.5
"""

import argparse
import random
import threading
import time

from repro import core as lp


class ParamServer:
    """1 ms simulated parameter fetch (the paper's workload). With a
    registry it advertises itself like an engine replica
    (role=param-server) and exposes the chaos hooks (kill/stall)."""

    def __init__(self, registry=None, name="server-0", heartbeat_s=0.1):
        self._served = 0
        self._dead = False
        self._name = name
        self._heartbeater = None
        if registry is not None:
            ctx = lp.get_current_context()
            self._heartbeater = lp.Heartbeater(
                registry, name, ctx.endpoint or f"inproc://{name}",
                load_fn=self.load, period_s=heartbeat_s,
                stop_event=ctx.stop_event).start()

    def load(self):
        return {"role": "param-server", "served": self._served}

    def kill(self):
        """Die unannounced: RPCs fail, heartbeats stop, the registry
        evicts via TTL (or sooner, via a requester's report_failure)."""
        self._dead = True
        if self._heartbeater is not None:
            self._heartbeater.stop(deregister=False)

    def stall(self, seconds):
        if self._heartbeater is not None:
            self._heartbeater.pause(seconds)

    def get_value(self):
        if self._dead:
            raise ConnectionError(f"{self._name} is dead")
        time.sleep(0.001)   # paper: 1ms simulated parameter-fetch delay
        self._served += 1
        return random.random()


class Requester:
    """Polls a server as fast as it can; reports its QPS to a meter.

    With a direct ``server`` handle this is Listing 3 verbatim. With a
    ``registry`` it resolves a live param-server by role instead, and
    fails over on error: report_failure evicts the dead server, the
    re-resolve lands on a survivor.
    """

    def __init__(self, meter, server=None, registry=None, index=0):
        self._meter = meter
        self._server = server
        self._registry = registry
        self._index = index
        self._resolved_name = None

    def _resolve(self):
        replicas = [r for r in self._registry.lookup()["replicas"]
                    if r["load"].get("role") == "param-server"
                    and not r.get("draining")]
        if not replicas:
            return None
        r = replicas[self._index % len(replicas)]
        self._resolved_name = r["name"]
        return lp.courier.client_for(r["endpoint"])

    def run(self):
        ctx = lp.get_current_context()
        server = self._server
        while not ctx.should_stop:
            if server is None:               # registry mode: (re-)resolve
                server = self._resolve()
                if server is None:           # nobody live yet / mid-failover
                    ctx.wait_for_stop(0.01)
                    continue
            try:
                server.get_value()
            except Exception:  # noqa: BLE001
                if self._registry is None:
                    raise                    # direct handle: let it surface
                try:
                    self._registry.report_failure(self._resolved_name)
                except Exception:  # noqa: BLE001
                    pass
                server = None
                continue
            self._meter.count(1)


class Meter:
    def __init__(self, seconds: float):
        self._n = 0
        self._lock = threading.Lock()
        self._seconds = seconds

    def count(self, k: int):
        with self._lock:
            self._n += k

    def run(self):
        time.sleep(self._seconds)
        with self._lock:
            qps = self._n / self._seconds
        print(f"total QPS: {qps:,.0f}")
        lp.stop_program()


def build(mode: str, num_requesters: int, seconds: float,
          num_servers: int = 4, cache_timeout: float = 0.01,
          kill_after=None) -> lp.Program:
    p = lp.Program(f"ps-{mode}")
    meter = p.add_node(lp.CourierNode(Meter, seconds))

    if mode == "single":
        with p.group("server"):
            server = p.add_node(lp.CourierNode(ParamServer))
        requesters = [dict(server=server)] * num_requesters
    elif mode == "replicated":
        with p.group("registry"):
            registry = p.add_node(lp.CourierNode(lp.Registry, ttl_s=2.0))
        with p.group("server"):
            for i in range(num_servers):
                p.add_node(lp.CourierNode(ParamServer, registry,
                                          name=f"server-{i}"))
        requesters = [dict(registry=registry, index=i)
                      for i in range(num_requesters)]
        if kill_after is not None:
            from repro.train.fabric import ChaosNode
            with p.group("chaos"):
                p.add_node(lp.PyNode(
                    ChaosNode, registry,
                    [("kill", "server-0", kill_after, 0.0)]))
    elif mode == "cached":
        with p.group("server"):
            server = p.add_node(lp.CourierNode(ParamServer))
        with p.group("cacher"):
            cacher = p.add_node(lp.CacherNode(server, timeout_s=cache_timeout))
        requesters = [dict(server=cacher)] * num_requesters
    else:
        raise ValueError(mode)
    if kill_after is not None and mode != "replicated":
        raise ValueError("--kill-after needs --mode replicated (the other "
                         "topologies have no failover path)")

    with p.group("requester"):
        for kwargs in requesters:
            p.add_node(lp.CourierNode(Requester, meter, **kwargs))
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="cached",
                    choices=["single", "replicated", "cached"])
    ap.add_argument("--requesters", type=int, default=8)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--seconds", type=float, default=2.0)
    ap.add_argument("--kill-after", type=float, default=None,
                    help="chaos demo (replicated only): kill server-0 this "
                         "many seconds after it registers; its requesters "
                         "fail over to the surviving replicas")
    args = ap.parse_args()
    program = build(args.mode, args.requesters, args.seconds,
                    num_servers=args.servers, kill_after=args.kill_after)
    print(program)
    lp.launch_and_wait(program, timeout_s=args.seconds + 30)


if __name__ == "__main__":
    main()
