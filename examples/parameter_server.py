"""Parameter server (paper §5.1, Listings 3/4, Figure 2).

Three topologies over the same services, selected by --mode:
  single      one server, N requesters (Listing 3)
  replicated  servers replicated, requesters partitioned (Listing 4 left)
  cached      one server behind a CacherNode (Listing 4 right)

    PYTHONPATH=src python examples/parameter_server.py --mode cached \
        --requesters 8 --seconds 2
"""

import argparse
import random
import threading
import time

from repro import core as lp


class ParamServer:
    def get_value(self):
        time.sleep(0.001)   # paper: 1ms simulated parameter-fetch delay
        return random.random()


class Requester:
    """Polls the server as fast as it can; reports its QPS to a meter."""

    def __init__(self, param_server, meter):
        self._server = param_server
        self._meter = meter

    def run(self):
        ctx = lp.get_current_context()
        n = 0
        while not ctx.should_stop:
            self._server.get_value()
            n += 1
            self._meter.count(1)
        del n


class Meter:
    def __init__(self, seconds: float):
        self._n = 0
        self._lock = threading.Lock()
        self._seconds = seconds

    def count(self, k: int):
        with self._lock:
            self._n += k

    def run(self):
        time.sleep(self._seconds)
        with self._lock:
            qps = self._n / self._seconds
        print(f"total QPS: {qps:,.0f}")
        lp.stop_program()


def build(mode: str, num_requesters: int, seconds: float,
          num_servers: int = 4, cache_timeout: float = 0.01) -> lp.Program:
    p = lp.Program(f"ps-{mode}")
    meter = p.add_node(lp.CourierNode(Meter, seconds))

    if mode == "single":
        with p.group("server"):
            server = p.add_node(lp.CourierNode(ParamServer))
        targets = [server] * num_requesters
    elif mode == "replicated":
        with p.group("server"):
            servers = [p.add_node(lp.CourierNode(ParamServer))
                       for _ in range(num_servers)]
        targets = [servers[i % num_servers] for i in range(num_requesters)]
    elif mode == "cached":
        with p.group("server"):
            server = p.add_node(lp.CourierNode(ParamServer))
        with p.group("cacher"):
            cacher = p.add_node(lp.CacherNode(server, timeout_s=cache_timeout))
        targets = [cacher] * num_requesters
    else:
        raise ValueError(mode)

    with p.group("requester"):
        for t in targets:
            p.add_node(lp.CourierNode(Requester, t, meter))
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="cached",
                    choices=["single", "replicated", "cached"])
    ap.add_argument("--requesters", type=int, default=8)
    ap.add_argument("--seconds", type=float, default=2.0)
    args = ap.parse_args()
    program = build(args.mode, args.requesters, args.seconds)
    print(program)
    lp.launch_and_wait(program, timeout_s=args.seconds + 30)


if __name__ == "__main__":
    main()
