"""Quickstart: the paper's Figure-1 producer-consumer program, verbatim
structure (Listing 2), on the thread launcher.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro import core as lp


class Range:
    """Produces sequential data on request from a given range."""

    def __init__(self, lo: int, hi: int):
        self._lo, self._hi = lo, hi

    def get(self):
        return list(range(self._lo, self._hi))


class Consumer:
    """Performs some calculation on the producers' outputs."""

    def __init__(self, producers):
        self._producers = producers

    def run(self):
        values = [p.get() for p in self._producers]
        total = sum(sum(v) for v in values)
        print(f"consumer received {values} -> total {total}")
        lp.stop_program()


def make_program() -> lp.Program:
    # Create an empty program graph.
    p = lp.Program("producer-consumer")

    # Add nodes producing a range of data.
    with p.group("producer"):
        r1 = p.add_node(lp.CourierNode(Range, 0, 10))
        r2 = p.add_node(lp.CourierNode(Range, 10, 20))

    # Add a node to consume from producers.
    with p.group("consumer"):
        p.add_node(lp.CourierNode(Consumer, [r1, r2]))
    return p


def main():
    program = make_program()
    print(program)
    lp.launch_and_wait(program, timeout_s=30)
    print("done.")


if __name__ == "__main__":
    main()
