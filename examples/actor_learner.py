"""Distributed actor–learner RL (paper §5.4, Listings 7/11) — on the
elastic training fabric.

Actors interact with a toy environment and push trajectories into a
registry-advertised replay service; learners sample batches and run a JAX
policy-gradient step. Unlike the original topology (actors fetch params
from the learner over ad-hoc RPC), everything here rides the fabric's
survival story:

  * the learner publishes params to a versioned ModelStore — actors pull
    consistent snapshots and a respawned learner resumes from the last
    published version (step loss <= --publish-every);
  * every worker heartbeats through the Registry; a TrainSupervisor
    respawns whoever dies under RestartPolicy backoff;
  * replay inserts carry a deadline — a dead learner surfaces to actors
    as a typed WriterStalled, and they re-resolve instead of deadlocking.

Environment: 1-D "target chase" — state is (pos, target); reward is
-|pos-target|; actions move ±1/0. Learnable in a few hundred steps.

    PYTHONPATH=src python examples/actor_learner.py --steps 150
    PYTHONPATH=src python examples/actor_learner.py --actors 4 --learners 2
    PYTHONPATH=src python examples/actor_learner.py --kill-after 2
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as lp
from repro.data.replay import TableConfig
from repro.train import fabric
from repro.train.optimizer import OptimizerConfig

GRID = 8
ACTIONS = 3  # left, stay, right
EPISODE_LEN = 16


class ChaseEnv:
    def __init__(self, rng):
        self._rng = rng
        self.reset()

    def reset(self):
        self._pos = int(self._rng.integers(0, GRID))
        self._target = int(self._rng.integers(0, GRID))
        return self._obs()

    def _obs(self):
        return np.array([self._pos, self._target], np.float32) / GRID

    def step(self, action):
        self._pos = int(np.clip(self._pos + (action - 1), 0, GRID - 1))
        reward = -abs(self._pos - self._target) / GRID
        return self._obs(), reward


def policy_logits(params, obs):
    h = jnp.tanh(obs @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


class PGTask:
    """Fabric task: REINFORCE on batches of trajectories."""

    optimizer = OptimizerConfig(lr=0.05, warmup_steps=0, total_steps=100_000,
                                weight_decay=0.0, clip_norm=None)

    def init_params(self, key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (2, 32)) * 0.5,
                "b1": jnp.zeros((32,)),
                "w2": jax.random.normal(k2, (32, ACTIONS)) * 0.5,
                "b2": jnp.zeros((ACTIONS,))}

    def grad_fn(self, params, batch):
        def loss_fn(p):
            logits = policy_logits(p, batch["obs"])      # [B, T, A]
            logp = jax.nn.log_softmax(logits)
            chosen = jnp.take_along_axis(
                logp, batch["act"][..., None], -1)[..., 0]
            adv = batch["ret"] - batch["ret"].mean()
            return -(chosen * adv).mean()
        return jax.value_and_grad(loss_fn)(params)

    def collate(self, items):
        rew = np.stack([it["rew"] for it in items])
        ret = rew[..., ::-1].cumsum(-1)[..., ::-1].copy()
        return {"obs": np.stack([it["obs"] for it in items]),
                "act": np.stack([it["act"] for it in items]),
                "ret": ret.astype(np.float32)}


def rollout(params, rng):
    """One episode under the current policy -> one replay item. Params are
    host numpy (pulled from the ModelStore), so act with numpy directly."""
    env = ChaseEnv(rng)
    obs = env.reset()
    traj_obs, traj_act, traj_rew = [], [], []
    for _ in range(EPISODE_LEN):
        h = np.tanh(obs @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        action = int(rng.choice(ACTIONS, p=probs))
        traj_obs.append(obs)
        traj_act.append(action)
        obs, reward = env.step(action)
        traj_rew.append(reward)
    return {"obs": np.stack(traj_obs), "act": np.array(traj_act),
            "rew": np.array(traj_rew, np.float32)}


class Fleet:
    """PyNode hosting the worker fleet on a ThreadWorkerSpawner, supervised
    by a TrainSupervisor until the chief learner reports done."""

    def __init__(self, registry, store_dir, num_actors, num_learners,
                 cfg: fabric.FabricConfig):
        self._registry = registry
        self._store_dir = store_dir
        self._actors = num_actors
        self._learners = num_learners
        self._cfg = cfg

    def run(self):
        spawner = fabric.ThreadWorkerSpawner()
        task = PGTask()
        cfg = self._cfg
        table = TableConfig("trajectories", max_size=2000, sampler="uniform",
                            min_size_to_sample=8)
        resolver = fabric.registry_resolver(self._registry, "replay")

        def spawn_fn(name):
            role, idx = name.rsplit("-", 1)
            if role == "replay":
                spawner.spawn(name, lambda n, ep: fabric.ReplayService(
                    [table], self._registry, name=n, endpoint=ep,
                    heartbeat_s=cfg.heartbeat_s))
            elif role == "learner":
                batch_fn = fabric.replay_batch_fn(
                    resolver, "trajectories", task.collate, cfg.batch_size,
                    cfg.sample_timeout_s)
                spawner.spawn(name, lambda n, ep: fabric.LearnerWorker(
                    task, batch_fn, self._store_dir, self._registry, cfg,
                    name=n, chief=(int(idx) == 0), endpoint=ep))
            elif role == "actor":
                spawner.spawn(name, lambda n, ep, i=int(idx):
                              fabric.ActorWorker(
                                  task, rollout, resolver, "trajectories",
                                  self._store_dir, self._registry, cfg,
                                  name=n, endpoint=ep, seed=100 + i))
            else:
                raise ValueError(name)

        sup = fabric.TrainSupervisor(
            self._registry, spawn_fn,
            expected={"replay": 1, "actor": self._actors,
                      "learner": self._learners},
            policy=lp.RestartPolicy(max_restarts=5, backoff_s=0.05),
            spawn_grace_s=15.0, total_steps=cfg.total_steps)
        try:
            sup.run()
        finally:
            for r in self._registry.lookup()["replicas"]:
                load = r["load"]
                if load.get("role") == "learner" and load.get("chief"):
                    print(f"chief done: step={load['step']} "
                          f"loss={load['loss']:.4f} v={load['version']}")
            spawner.stop_all()


def build(num_actors=4, steps=150, num_learners=1, publish_every=10,
          kill_after=None, ckpt_dir=None) -> lp.Program:
    ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="actor_learner_")
    cfg = fabric.FabricConfig(
        total_steps=steps, batch_size=8, publish_every=publish_every,
        peer_timeout_s=10.0, heartbeat_s=0.2, insert_timeout_s=1.0,
        sample_timeout_s=1.0)
    p = lp.Program("actor-learner")
    with p.group("registry"):
        registry = p.add_node(lp.CourierNode(lp.Registry, ttl_s=10.0))
    with p.group("fleet"):
        p.add_node(lp.PyNode(Fleet, registry, ckpt_dir, num_actors,
                             num_learners, cfg))
    if kill_after is not None:
        with p.group("chaos"):
            p.add_node(lp.PyNode(
                fabric.ChaosNode, registry,
                [("kill", "learner-0", kill_after, 0.0)]))
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--actors", type=int, default=4)
    ap.add_argument("--learners", type=int, default=1)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--publish-every", type=int, default=10)
    ap.add_argument("--kill-after", type=float, default=None,
                    help="chaos demo: kill the chief learner this many "
                         "seconds after it comes up; the supervisor "
                         "restores it from the last published version")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    lp.launch_and_wait(
        build(args.actors, args.steps, num_learners=args.learners,
              publish_every=args.publish_every, kill_after=args.kill_after,
              ckpt_dir=args.ckpt_dir),
        timeout_s=600)


if __name__ == "__main__":
    main()
