"""Distributed actor–learner RL (paper §5.4, Listings 7/11).

Actors interact with a toy environment and push trajectories into a
ReverbNode table (rate-limited, paper §4.2 "data services"); a Learner
samples batches, runs a JAX policy-gradient step, and serves parameters
back to the actors — the exact topology of the paper with our replay
substrate underneath.

Environment: 1-D "target chase" — state is (pos, target); reward is
-|pos-target|; actions move ±1/0. Learnable in a few hundred steps.

    PYTHONPATH=src python examples/actor_learner.py --steps 150
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as lp
from repro.data.replay import TableConfig

GRID = 8
ACTIONS = 3  # left, stay, right


class ChaseEnv:
    def __init__(self, seed):
        self._rng = np.random.default_rng(seed)
        self.reset()

    def reset(self):
        self._pos = int(self._rng.integers(0, GRID))
        self._target = int(self._rng.integers(0, GRID))
        return self._obs()

    def _obs(self):
        return np.array([self._pos, self._target], np.float32) / GRID

    def step(self, action):
        self._pos = int(np.clip(self._pos + (action - 1), 0, GRID - 1))
        reward = -abs(self._pos - self._target) / GRID
        return self._obs(), reward


def policy_logits(params, obs):
    h = jnp.tanh(obs @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


class Actor:
    def __init__(self, learner, replay, seed, episode_len=16):
        self._learner = learner
        self._replay = replay
        self._env = ChaseEnv(seed)
        self._rng = np.random.default_rng(seed + 1)
        self._episode_len = episode_len

    def run(self):
        ctx = lp.get_current_context()
        params = self._learner.get_params()
        while not ctx.should_stop:
            obs = self._env.reset()
            traj_obs, traj_act, traj_rew = [], [], []
            for _ in range(self._episode_len):
                logits = np.asarray(policy_logits(
                    jax.tree.map(jnp.asarray, params), jnp.asarray(obs)))
                probs = np.exp(logits - logits.max())
                probs /= probs.sum()
                action = int(self._rng.choice(ACTIONS, p=probs))
                traj_obs.append(obs)
                traj_act.append(action)
                obs, reward = self._env.step(action)
                traj_rew.append(reward)
            ok = self._replay.insert("trajectories", {
                "obs": np.stack(traj_obs), "act": np.array(traj_act),
                "rew": np.array(traj_rew, np.float32)}, timeout=5.0)
            if ok:
                params = self._learner.get_params()  # periodic param fetch


class Learner:
    def __init__(self, replay, steps=150, batch_size=8, lr=0.05):
        self._replay = replay
        self._steps = steps
        self._batch = batch_size
        key = jax.random.key(0)
        k1, k2 = jax.random.split(key)
        self._params = {
            "w1": jax.random.normal(k1, (2, 32)) * 0.5,
            "b1": jnp.zeros((32,)),
            "w2": jax.random.normal(k2, (32, ACTIONS)) * 0.5,
            "b2": jnp.zeros((ACTIONS,)),
        }
        self._lr = lr
        self._update = jax.jit(self._pg_step)

    def _pg_step(self, params, obs, act, ret):
        def loss_fn(p):
            logits = policy_logits(p, obs)          # [B, T, A]
            logp = jax.nn.log_softmax(logits)
            chosen = jnp.take_along_axis(logp, act[..., None], -1)[..., 0]
            adv = ret - ret.mean()
            return -(chosen * adv).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, g: p - self._lr * g, params, grads)
        return params, loss

    def get_params(self):
        return jax.tree.map(np.asarray, self._params)

    def run(self):
        returns = []
        for step in range(self._steps):
            batch = self._replay.sample("trajectories", self._batch,
                                        timeout=30.0)
            if batch is None:
                print("learner: replay timed out")
                break
            obs = jnp.asarray(np.stack([b["obs"] for b in batch]))
            act = jnp.asarray(np.stack([b["act"] for b in batch]))
            rew = np.stack([b["rew"] for b in batch])
            ret = jnp.asarray((rew[..., ::-1].cumsum(-1)[..., ::-1]).copy())
            self._params, loss = self._update(self._params, obs, act, ret)
            returns.append(float(rew.sum(-1).mean()))
            if step % 25 == 0 or step == self._steps - 1:
                print(f"step {step:4d} loss={float(loss):7.4f} "
                      f"mean_episode_return={np.mean(returns[-25:]):7.3f}")
        early = np.mean(returns[:20])
        late = np.mean(returns[-20:])
        print(f"return improved {early:.3f} -> {late:.3f}")
        lp.stop_program()


def build(num_actors=4, steps=150) -> lp.Program:
    p = lp.Program("actor-learner")
    replay = p.add_node(lp.ReverbNode([TableConfig(
        "trajectories", max_size=2000, sampler="uniform",
        min_size_to_sample=8)]))
    with p.group("learner"):
        learner = p.add_node(lp.CourierNode(Learner, replay, steps=steps))
    with p.group("actor"):
        for i in range(num_actors):
            p.add_node(lp.CourierNode(Actor, learner, replay, seed=i))
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--actors", type=int, default=4)
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    lp.launch_and_wait(build(args.actors, args.steps), timeout_s=600)


if __name__ == "__main__":
    main()
