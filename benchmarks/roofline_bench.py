"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/single/*.json; emits one row per runnable cell with
the three terms and the bound. Derived column packs the full detail.
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun",
                   "single")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run(emit):
    recs = rows()
    if not recs:
        emit("roofline/NO_ARTIFACTS", 0.0,
             "run: python -m repro.launch.dryrun --mesh single")
        return
    for d in recs:
        name = f"roofline/{d['arch']}/{d['shape']}"
        if d["status"] == "skipped":
            emit(name, 0.0, f"skipped:{d['reason'][:40]}")
            continue
        if d["status"] != "ok" or "roofline" not in d:
            emit(name, 0.0, f"status={d['status']}")
            continue
        r = d["roofline"]
        emit(name, r["step_s"] * 1e6,
             f"bound={r['bound']};ct={r['compute_s']:.4f}s;"
             f"mt={r['memory_s']:.4f}s;colt={r['collective_s']:.4f}s;"
             f"mfu={r['mfu']:.4f};useful={r['useful_flops_ratio']:.2f};"
             f"peak={d['memory']['peak_estimate_gb']:.1f}GB")
