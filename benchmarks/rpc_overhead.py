"""Paper §1 claim: "Launchpad adds no additional overhead — communication
between individual services will be just as fast as the underlying
communication protocol." Measured: direct python call vs in-process
courier channel vs the two cross-process transports — courier-over-gRPC
and the shared-memory ring (``shm://``) — with a payload sweep (1 KiB ->
8 MiB), batched RPC amortization, and the pre-refactor ("legacy") wire
format as a gRPC A/B baseline.

The cross-process arms (``rpc/shm/*``, ``rpc/shm_copy/*``,
``rpc/grpc/*``, ``rpc/grpc_legacy/*``) run against ONE forked server
process that serves both transports at once — the same-host
process-launcher topology the shm transport exists for — and are
measured *paired*: the arms alternate chunk-by-chunk per payload so they
see identical background conditions. ``rpc/shm_copy`` is the PR-2
receive path (one full copy-out per large message on each side) over the
same connection machinery, so shm vs shm_copy isolates exactly what the
zero-copy slot-pool receive buys. (Before the shm transport landed,
rpc/grpc/* was measured against an in-process loopback server; absolute
values are not comparable across that change.)
"""

from __future__ import annotations

import multiprocessing as mp
import os
import time

import numpy as np

from repro.core import courier
from repro.core.courier.client import CourierClient
from repro.core.courier.server import CourierServer
from repro.core.courier.transport import ShmTransport


class Echo:
    def ping(self):
        return 1

    def echo(self, x):
        return x


# (label, payload bytes, iterations) — fewer iterations as payloads grow.
PAYLOADS = [
    ("1k", 1024, 300),
    ("64k", 64 * 1024, 200),
    ("1m", 1 << 20, 160),
    ("8m", 8 << 20, 24),
]


def _time_call(fn, n: int, repeats: int = 8) -> float:
    """us/call, min over ``repeats`` chunks (robust to scheduler noise)."""
    fn()  # warmup
    chunk = max(1, n // repeats)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(chunk):
            fn()
        best = min(best, (time.perf_counter() - t0) / chunk)
    return best * 1e6


def _sweep(emit, prefix: str, call, derived_first: str = "") -> None:
    for label, size, n in PAYLOADS:
        payload = np.zeros(size, np.uint8)
        emit(f"{prefix}/echo{label}",
             _time_call(lambda p=payload: call(p), n),
             derived_first if label == PAYLOADS[0][0] else "")


def _paired_chunks(arms, n: int, repeats: int = 12) -> dict[str, float]:
    """us/call per arm, min over ``repeats`` chunks with the arms
    alternating chunk-by-chunk so every arm sees the same background
    conditions (sequential sweeps drift apart on noisy shared hosts)."""
    chunk = max(1, n // repeats)
    for _, call in arms:
        call()  # warm every arm (incl. bulk-slot creation / page faults)
        call()
    best = {name: float("inf") for name, _ in arms}
    for _ in range(repeats):
        for name, call in arms:
            t0 = time.perf_counter()
            for _ in range(chunk):
                call()
            best[name] = min(best[name], (time.perf_counter() - t0) / chunk)
    return {name: us * 1e6 for name, us in best.items()}


def _paired_sweep(emit, arms: list[tuple[str, object]],
                  derived: dict[str, str]) -> None:
    for label, size, n in PAYLOADS:
        payload = np.zeros(size, np.uint8)
        best = _paired_chunks(
            [(name, lambda call=call: call(payload)) for name, call in arms],
            n)
        for name, _ in arms:
            emit(f"{name}/echo{label}", best[name],
                 derived.get(name, "") if label == PAYLOADS[0][0] else "")


def _ser_sweep(emit) -> None:
    """Wire-format cost in isolation (no transport): encode + decode."""
    from repro.core.courier import serialization as ser
    for label, size, _ in PAYLOADS[-2:]:  # 1 MiB and 8 MiB
        msg = ("echo", (np.zeros(size, np.uint8),), {})
        framed, legacy = ser.dumps(msg), ser.legacy_dumps(msg)
        buf = bytearray(ser.framed_size(ser.encode_frames(msg)))
        emit(f"ser/frames/enc{label}", _time_call(lambda: ser.dumps(msg), 64),
             "out-of-band buffers")
        emit(f"ser/scatter/enc{label}",
             _time_call(lambda: ser.encode_call_into(buf, *msg), 64),
             "encode_call_into (no join)")
        emit(f"ser/legacy/enc{label}",
             _time_call(lambda: ser.legacy_dumps(msg), 64), "in-band pickle")
        emit(f"ser/frames/dec{label}", _time_call(lambda: ser.loads(framed), 64),
             "zero-copy views")
        emit(f"ser/legacy/dec{label}", _time_call(lambda: ser.loads(legacy), 64),
             "")


def _server_child(shm_name: str, endpoint_q, stop_ev) -> None:
    srv = CourierServer(Echo(), shm_name=shm_name)
    srv.start()
    endpoint_q.put(srv.endpoint)
    stop_ev.wait()
    srv.stop()


def run(emit):
    obj = Echo()
    n_ping = 300

    emit("rpc/direct/ping", _time_call(obj.ping, n_ping), "baseline")
    _sweep(emit, "rpc/direct", obj.echo)

    courier.inprocess.register("echo_bench", obj)
    with courier.client_for("inproc://echo_bench") as cli:
        emit("rpc/inproc/ping", _time_call(cli.ping, n_ping),
             "same-process channel")
        _sweep(emit, "rpc/inproc", cli.echo)
    courier.inprocess.unregister("echo_bench")

    # Cross-process: one server process serving shm + gRPC at once, so the
    # arms are a true A/B over identical dispatch.
    ctx = mp.get_context("fork")
    shm_name = f"bench{os.getpid():x}"
    endpoint_q = ctx.Queue()
    stop_ev = ctx.Event()
    child = ctx.Process(target=_server_child,
                        args=(shm_name, endpoint_q, stop_ev), daemon=True)
    child.start()
    grpc_ep = endpoint_q.get(timeout=30)
    try:
        with courier.client_for(f"shm://{shm_name}+{grpc_ep}") as s, \
                CourierClient(None, transport=ShmTransport(
                    shm_name, zero_copy=False)) as sc, \
                courier.client_for(grpc_ep) as g, \
                CourierClient(grpc_ep, wire_format="legacy") as gl:
            assert isinstance(s.transport, courier.ShmTransport)
            pings = _paired_chunks(
                [("rpc/shm", s.ping), ("rpc/grpc", g.ping),
                 ("rpc/grpc_legacy", gl.ping)], n_ping)
            emit("rpc/shm/ping", pings["rpc/shm"], "shared-memory ring")
            emit("rpc/grpc/ping", pings["rpc/grpc"],
                 "courier-over-grpc framed wire format")
            emit("rpc/grpc_legacy/ping", pings["rpc/grpc_legacy"],
                 "pre-refactor wire format")
            _paired_sweep(
                emit,
                [("rpc/shm", s.echo), ("rpc/shm_copy", sc.echo),
                 ("rpc/grpc", g.echo), ("rpc/grpc_legacy", gl.echo)],
                derived={"rpc/shm": "zero-copy slot-pool receive",
                         "rpc/shm_copy": "PR-2 copy-out receive (A/B)",
                         "rpc/grpc": "paired vs shm"})
            # Batched RPC: 64 pings in one frame vs 64 single round trips.
            batch = [("ping", (), {})] * 64
            emit("rpc/shm/ping_batched64",
                 _time_call(lambda: s.batch_call(batch), 50) / 64,
                 "per-call cost at 64 calls/frame")
            emit("rpc/grpc/ping_batched64",
                 _time_call(lambda: g.batch_call(batch), 50) / 64,
                 "per-call cost at 64 calls/frame")
    finally:
        stop_ev.set()
        child.join(timeout=10)
        if child.is_alive():
            child.terminate()

    _ser_sweep(emit)
