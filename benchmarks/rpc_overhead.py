"""Paper §1 claim: "Launchpad adds no additional overhead — communication
between individual services will be just as fast as the underlying
communication protocol." Measured: direct python call vs in-process
courier channel vs courier-over-gRPC, same payloads.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import courier
from repro.core.courier.server import CourierServer


class Echo:
    def ping(self):
        return 1

    def echo(self, x):
        return x


def _time_call(fn, n: int) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(emit):
    obj = Echo()
    payload = np.zeros(64 * 1024, np.uint8)   # 64 KiB
    n = 300

    emit("rpc/direct/ping", _time_call(obj.ping, n), "baseline")
    emit("rpc/direct/echo64k", _time_call(lambda: obj.echo(payload), n), "")

    courier.inprocess.register("echo_bench", obj)
    cli = courier.client_for("inproc://echo_bench")
    emit("rpc/inproc/ping", _time_call(cli.ping, n), "shared-memory channel")
    emit("rpc/inproc/echo64k", _time_call(lambda: cli.echo(payload), n), "")
    courier.inprocess.unregister("echo_bench")

    srv = CourierServer(obj)
    srv.start()
    g = courier.client_for(srv.endpoint)
    emit("rpc/grpc/ping", _time_call(g.ping, n), "courier-over-grpc")
    emit("rpc/grpc/echo64k", _time_call(lambda: g.echo(payload), n), "")
    srv.stop()
