"""Paper §1 claim: "Launchpad adds no additional overhead — communication
between individual services will be just as fast as the underlying
communication protocol." Measured: direct python call vs in-process
courier channel vs courier-over-gRPC, with a payload sweep (1 KiB ->
8 MiB), the pre-refactor ("legacy") wire format as the A/B baseline over
the same server, and batched RPC amortization.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import courier
from repro.core.courier.client import CourierClient
from repro.core.courier.server import CourierServer


class Echo:
    def ping(self):
        return 1

    def echo(self, x):
        return x


# (label, payload bytes, iterations) — fewer iterations as payloads grow.
PAYLOADS = [
    ("1k", 1024, 300),
    ("64k", 64 * 1024, 200),
    ("1m", 1 << 20, 160),
    ("8m", 8 << 20, 24),
]


def _time_call(fn, n: int, repeats: int = 8) -> float:
    """us/call, min over ``repeats`` chunks (robust to scheduler noise)."""
    fn()  # warmup
    chunk = max(1, n // repeats)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(chunk):
            fn()
        best = min(best, (time.perf_counter() - t0) / chunk)
    return best * 1e6


def _sweep(emit, prefix: str, call, derived_first: str = "") -> None:
    for label, size, n in PAYLOADS:
        payload = np.zeros(size, np.uint8)
        emit(f"{prefix}/echo{label}",
             _time_call(lambda p=payload: call(p), n),
             derived_first if label == PAYLOADS[0][0] else "")


def _ab_sweep(emit, framed_call, legacy_call) -> None:
    """Paired A/B: alternate framed/legacy chunks per payload so both see
    the same background conditions (sequential sweeps drift apart on noisy
    shared hosts)."""
    for label, size, n in PAYLOADS:
        payload = np.zeros(size, np.uint8)
        chunk = max(1, n // 8)
        framed_call(payload)
        legacy_call(payload)  # warm both paths
        best = {"frames": float("inf"), "legacy": float("inf")}
        for _ in range(8):
            for key, call in (("frames", framed_call), ("legacy", legacy_call)):
                t0 = time.perf_counter()
                for _ in range(chunk):
                    call(payload)
                best[key] = min(best[key],
                                (time.perf_counter() - t0) / chunk)
        emit(f"rpc/grpc/echo{label}", best["frames"] * 1e6, "")
        emit(f"rpc/grpc_legacy/echo{label}", best["legacy"] * 1e6, "")


def _ser_sweep(emit) -> None:
    """Wire-format cost in isolation (no gRPC): encode + decode per format."""
    from repro.core.courier import serialization as ser
    for label, size, _ in PAYLOADS[-2:]:  # 1 MiB and 8 MiB
        msg = ("echo", (np.zeros(size, np.uint8),), {})
        framed, legacy = ser.dumps(msg), ser.legacy_dumps(msg)
        emit(f"ser/frames/enc{label}", _time_call(lambda: ser.dumps(msg), 64),
             "out-of-band buffers")
        emit(f"ser/legacy/enc{label}",
             _time_call(lambda: ser.legacy_dumps(msg), 64), "in-band pickle")
        emit(f"ser/frames/dec{label}", _time_call(lambda: ser.loads(framed), 64),
             "zero-copy views")
        emit(f"ser/legacy/dec{label}", _time_call(lambda: ser.loads(legacy), 64),
             "")


def run(emit):
    obj = Echo()
    n_ping = 300

    emit("rpc/direct/ping", _time_call(obj.ping, n_ping), "baseline")
    _sweep(emit, "rpc/direct", obj.echo)

    courier.inprocess.register("echo_bench", obj)
    with courier.client_for("inproc://echo_bench") as cli:
        emit("rpc/inproc/ping", _time_call(cli.ping, n_ping),
             "shared-memory channel")
        _sweep(emit, "rpc/inproc", cli.echo)
    courier.inprocess.unregister("echo_bench")

    srv = CourierServer(obj)
    srv.start()
    try:
        # Framed (new) vs pre-refactor wire format over the SAME server (it
        # mirrors the request's format): the A/B for the zero-copy win.
        with courier.client_for(srv.endpoint) as g, \
                CourierClient(srv.endpoint, wire_format="legacy") as gl:
            emit("rpc/grpc/ping", _time_call(g.ping, n_ping),
                 "courier-over-grpc framed wire format")
            emit("rpc/grpc_legacy/ping", _time_call(gl.ping, n_ping),
                 "pre-refactor wire format")
            _ab_sweep(emit, g.echo, gl.echo)
            # Batched RPC: 64 pings in one frame vs 64 single round trips.
            batch = [("ping", (), {})] * 64
            us_batch = _time_call(lambda: g.batch_call(batch), 50) / 64
            emit("rpc/grpc/ping_batched64", us_batch,
                 "per-call cost at 64 calls/frame")
    finally:
        srv.stop()
        srv.stop()  # idempotent double-stop (exercised on purpose)

    _ser_sweep(emit)
