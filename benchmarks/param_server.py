"""Paper Figure 2: parameter-server QPS vs #requesters for the three
topologies (single / replicated / cached). Uses the example's services on
the thread launcher with real gRPC channels optional.
"""

from __future__ import annotations

import importlib.util
import os
import sys
import threading
import time

from repro import core as lp


def _load_example():
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "parameter_server.py")
    spec = importlib.util.spec_from_file_location("ps_example",
                                                  os.path.abspath(path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def measure(mode: str, num_requesters: int, seconds: float = 1.0) -> float:
    ex = _load_example()
    qps_out = {}

    class Meter(ex.Meter):
        def run(self):
            time.sleep(self._seconds)
            with self._lock:
                qps_out["qps"] = self._n / self._seconds
            lp.stop_program()

    ex.Meter = Meter
    program = ex.build(mode, num_requesters, seconds)
    lp.launch_and_wait(program, timeout_s=seconds + 60)
    return qps_out["qps"]


def run(emit):
    """emit(name, us_per_call, derived)"""
    base = None
    for mode in ("single", "replicated", "cached"):
        for n in (1, 4, 8):
            qps = measure(mode, n, seconds=1.0)
            if base is None:
                base = qps
            emit(f"param_server/{mode}/n{n}",
                 1e6 / max(qps, 1e-9),
                 f"qps={qps:.0f};rel={qps / base:.2f}")
