"""End-to-end serve benchmark: continuous batching vs the lockstep baseline.

Paired A/B of the two serving policies over the SAME model, request
stream, and arrival schedule — the only variable is iteration-level
scheduling:

  * ``lockstep``: static-shape batching, the strongest simple baseline on
    a recompile-happy backend — a coalescing worker drains up to
    ``MAX_BATCH`` queued prompts (or waits ``MAX_WAIT_S``), pads the
    group to a fixed ``(MAX_BATCH, S_max)`` shape (one jit executable,
    zero mid-run recompiles), and runs prefill plus ``max(max_new in
    group)`` decode steps once per batch: every request waits for its
    batch boundary, and the whole batch waits for its slowest member.
    Ragged rows use the length mask, so the comparison is
    correctness-for-correctness.
  * ``continuous``: :class:`repro.serve.engine.ServeEngine` — arrivals
    admitted into free KV-cache slots between decode steps (exact-length
    prefill), sequences retire their slot the moment their own budget is
    done, replies stream back per request. Pinned to ``sync_every=1,
    decode_impl="dense"``: this arm IS the PR-5 engine, kept as the
    paired baseline for the fused arm below.
  * ``fused``: the same engine with the roofline-decode path on —
    ``sync_every=8`` fused sampling windows (one host sync per K-token
    block) and ``decode_impl="flash"`` (the kernels.ops dispatcher:
    Pallas flash-decode on TPU, its jit'd oracle elsewhere). Same
    request stream and schedule; ``serve/fused/mixed/syncs_per_tok``
    reports measured host syncs per generated token (CI gates <= 0.25).
  * ``paged``: the fused configuration over the paged KV pool —
    ``page_size=8``, a pool holding EXACTLY the flat fused arm's KV
    bytes (``NUM_SLOTS`` full rings) but admitting 1.5x the rows,
    because each request reserves only the pages its own prompt+budget
    needs instead of a worst-case ring (the row count is sized to what
    the pool can back at this mix — see PAGED_SLOTS). Equal memory,
    higher admissible concurrency on mixed-length traffic: CI gates
    paged us/tok <= fused us/tok at "mixed".

Two further paired A/Bs ride on the paged pool and the router:

  serve/prefix_{on,off}/shared/{tok,p95} — shared-system-prompt
      schedule (every prompt = one 128-token system prefix + a short
      unique tail) over IDENTICAL paged engines, prefix cache on vs
      off. "On" admits warm requests by ref-counting the cached prefix
      pages and prefilling only the tail (copy-on-write); "off" pays
      the full prompt every time. CI gates on >= 1.3x off tok/s.
  serve/fabric/dispatch_{coalesced,percall} — the router's dispatch
      path with frame coalescing on vs off on the same paced r1 run:
      coalesced drains concurrent arrivals into ONE courier
      ``batch_call`` frame per replica; percall pays one RPC per
      request. Derived column reports mean_calls_per_frame.

Requests mix prompt lengths AND decode budgets (real traffic stops at
EOS at different depths); that mix is precisely what lockstep cannot
exploit — a 4-token request pinned in a batch with a 16-token one holds
its slot for 16 steps. Arrival schedules are seeded pseudo-Poisson,
calibrated against the measured decode-step time of this host so "heavy"
means the same relative load everywhere. Warmup requests run every jit
shape before the measured window; compile time is excluded from both
arms.

Rows (us_per_call column):
  serve/{arm}/{scenario}/tok — microseconds per *generated* token
                               (derived: tok_s, mean slot occupancy)
  serve/{arm}/{scenario}/p50 — per-request latency p50, microseconds
  serve/{arm}/{scenario}/p95 — per-request latency p95, microseconds

The **serve fabric** arms measure the replicated control plane
(Registry + Router + heartbeats, ``repro/serve/router.py``) built over
this engine:

  serve/fabric/real1/mixed/*  — ONE real engine behind the full fabric
      on the same mixed schedule as the PR-4 arms: the paired A/B that
      prices the control plane (registry + router dispatch) itself.
  serve/fabric/dispatch       — router-added microseconds per dispatch
      attempt (admission -> dispatch bookkeeping), emitted from the
      1-replica *paced* run below, where the data plane sleeps instead
      of fighting the router for the GIL (co-located with real XLA the
      number measures 2-CPU GIL timeslices, not router cost).
  serve/fabric/r{1,2,4}/mixed/* — the scaling arm: 1/2/4 replicas on
      the SAME seeded arrival schedule. Replicas here are *paced*: the
      full fabric (registry, heartbeats, router, courier RPC) is real,
      but each replica's decode step costs a fixed wall-clock time
      calibrated from the real engine's measured step on this host,
      the way a replica backed by its own accelerator would. This CI
      host has 2 CPUs — real XLA replicas would fight over them and
      measure core contention, not fabric scaling (measured: 2 engines
      reach 1.38x, 4 reach 1.18x, pure oversubscription); with paced
      replicas a flat r2/r1 means the *router* serialized dispatch.
  serve/fabric/kill/*         — kill-one-replica-mid-run over REAL
      engines: lost-request count (target: zero — in-flight requests
      fail over to the sibling) and recovery time.

The **telemetry** arms price and validate the PR-10 observability layer
(``repro/core/telemetry.py``):

  serve/telemetry_{off,on}/mixed/tok + serve/telemetry/overhead_x —
      paired A/B of the paced r1 fabric on the same seeded schedule,
      tracing off vs EVERY request traced end-to-end. CI gates
      on <= 1.03x off us/token: observability must be ~free.
  serve/telemetry/coverage    — one dedicated sampled request through
      the warm real-engine fabric; the union of its exported spans
      (queue/dispatch/admission/prefill/decode/reply) must account for
      >= 0.95 of the client-measured e2e latency (CI-gated) — the
      trace explains every microsecond, not just the flattering ones.
  serve/telemetry/ttft/{class}/{p50,p95} — time-to-first-token from
      the engine's own log2-bucket histograms, per prefill class
      (direct vs chunked), scoped to the real1 measured window.

``REPRO_SMOKE=1`` shrinks to the CI-gated scenarios ("mixed" plus the
long-tail mix) with fewer requests. CI gates: continuous us/tok <
lockstep us/tok AND continuous p95 <= 1.05 * lockstep p95 at "mixed";
paged us/tok <= fused us/tok at "mixed"; prefix_on >= 1.3x prefix_off
tok/s; fabric r2 >= 1.6x r1 tok/s and r4 >= 2.5x r1; kill scenario
loses zero requests.
"""

from __future__ import annotations

import os
import queue
import random
import threading
import time
from concurrent import futures as cf

import numpy as np

from repro.core import courier, telemetry
from repro.core.discovery import Heartbeater, Registry
from repro.serve.router import Router, decorrelated_backoff, is_overloaded

MAX_BATCH = 8
MAX_WAIT_S = 0.02
NUM_SLOTS = 8

# (prompt_len, max_new) cycled per request. Budgets deliberately do not
# track lengths, like EOS depth in real traffic.
MIXES = {
    "mixed": ((4, 16), (12, 4), (24, 8), (8, 12)),
    "uniform": ((8, 8),),
    # Long-tail (Zipf-ish) prompt lengths: mostly short prompts with a
    # thin tail of long ones — the shape real traffic has, and the one a
    # flat per-row ring wastes the most KV memory on (every row pays the
    # full max-L ring; the paged pool pays per page actually reserved).
    "longtail": ((4, 8), (5, 4), (4, 12), (6, 8), (4, 4), (9, 8),
                 (4, 16), (6, 4), (12, 8), (4, 8), (18, 4), (24, 16)),
}
S_MAX = max(ln for m in MIXES.values() for ln, _ in m)
NEW_MAX = max(mn for m in MIXES.values() for _, mn in m)
CONTEXT_LEN = S_MAX + NEW_MAX

# Paged arm geometry: pages sized so the pool holds EXACTLY the flat
# fused arm's KV bytes (NUM_SLOTS full rings) — the equal-memory
# comparison is the whole point. Rows are sized to what the pool can
# actually BACK at this mix (~3 pages/request reserved -> ~13 rows from
# 40 pages), i.e. 1.5x the flat arm's. Compact windows make idle rows
# ~free (the window runs at the active count), but rows the pool can
# never back would still inflate the width ladder for nothing.
PAGE_SIZE = 8
NUM_PAGES = NUM_SLOTS * (CONTEXT_LEN // PAGE_SIZE)
PAGED_SLOTS = NUM_SLOTS + NUM_SLOTS // 2


def _smoke() -> bool:
    return os.environ.get("REPRO_SMOKE", "") == "1"


class LockstepServer:
    """In-process mirror of the lockstep Batcher+ModelServer pair.

    One jit executable: every batch is padded to (max_batch, s_max) —
    short groups carry dummy rows, short prompts carry pad tokens (masked
    by ``lengths``) — and decodes for the *largest* budget in the group.
    ``submit`` returns a Future resolving to the request's own
    [len + max_new] sequence.
    """

    def __init__(self, cfg, params, *, max_batch: int = MAX_BATCH,
                 max_wait_s: float = MAX_WAIT_S):
        self._cfg, self._params = cfg, params
        self._max_batch, self._max_wait = max_batch, max_wait_s
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._widths: list[int] = []
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, prompt, max_new: int) -> cf.Future:
        fut: cf.Future = cf.Future()
        fut.set_running_or_notify_cancel()
        self._q.put((np.asarray(prompt, np.int32), int(max_new), fut))
        return fut

    def _generate(self, batch, lengths, steps):
        import jax.numpy as jnp
        from repro.serve import decode as serve_lib
        # context_len is pinned to the worst case so prefill+step keep ONE
        # compiled shape; ``steps`` only changes the python loop length.
        return np.asarray(serve_lib.generate(
            self._cfg, self._params, jnp.asarray(batch), max_new=steps,
            context_len=CONTEXT_LEN, lengths=lengths))

    def _loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            group = [first]
            deadline = time.monotonic() + self._max_wait
            while len(group) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    group.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            batch = np.zeros((self._max_batch, S_MAX), np.int32)
            lengths = np.full((self._max_batch,), S_MAX, np.int32)
            for row, (p, _, _) in enumerate(group):
                batch[row, :len(p)] = p
                lengths[row] = len(p)
            steps = max(mn for _, mn, _ in group)   # slowest member rules
            self._widths.append(len(group))
            try:
                out = self._generate(batch, lengths, steps)
            except BaseException as exc:  # noqa: BLE001
                for _, _, fut in group:
                    fut.set_exception(exc)
                continue
            for row, (p, mn, fut) in enumerate(group):
                fut.set_result(out[row, :len(p) + mn])

    def mean_width(self) -> float:
        return float(np.mean(self._widths)) if self._widths else 0.0

    def reset_stats(self) -> None:
        self._widths.clear()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10)


def _drive(submit, requests, gaps):
    """Replay an arrival schedule against ``submit(prompt, max_new)``;
    returns (latencies_s, new_tokens_total, makespan_s)."""
    lock = threading.Lock()
    lats: list[float] = []
    done_at = [0.0]

    def track(fut, t_arr):
        def _cb(f):
            now = time.perf_counter()
            with lock:
                lats.append(now - t_arr)
                done_at[0] = max(done_at[0], now)
        fut.add_done_callback(_cb)

    futs = []
    t_start = time.perf_counter()
    t_next = t_start
    for (p, mn), gap in zip(requests, gaps):
        now = time.perf_counter()
        if now < t_next:
            time.sleep(t_next - now)
        t_arr = time.perf_counter()
        fut = submit(p, mn)
        track(fut, t_arr)
        futs.append(fut)
        t_next = t_arr + gap
    new_tokens = 0
    for (p, _), f in zip(requests, futs):
        new_tokens += len(f.result(timeout=600)) - len(p)
    return np.array(lats), new_tokens, done_at[0] - t_start


def _calibrate_step(engine, rng, vocab, n_steps: int = 20) -> float:
    """Median decode-step seconds at full occupancy (engine pre-warmed)."""
    for _ in range(engine.num_slots):
        engine.submit(rng.integers(0, vocab, 8, dtype=np.int32),
                      max_new=n_steps + 4)
    times = []
    while engine.stats()["free_slots"] > 0 or len(times) < n_steps:
        t0 = time.perf_counter()
        if engine.step() == 0:
            break
        times.append(time.perf_counter() - t0)
    while engine.step():
        pass                                    # drain
    return float(np.median(times))


def _make_requests(rng, vocab, mix, n_req):
    return [(rng.integers(0, vocab, mix[i % len(mix)][0], dtype=np.int32),
             mix[i % len(mix)][1]) for i in range(n_req)]


def run(emit) -> None:
    import jax
    from repro import configs
    from repro.models import transformer
    from repro.serve.engine import ServeEngine

    smoke = _smoke()
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    # >2 pools' worth of the paged arm's rows even in smoke: fewer
    # requests never saturate the larger row count, and the paged-vs-
    # flat pair degenerates to measuring the drain tail.
    n_req = 32 if smoke else 48

    # One engine per arm, reused across scenarios: its jit caches are the
    # warmup. The continuous arm is pinned to the PR-5 configuration
    # (sync every step, dense decode) so the fused arm has a stable
    # paired baseline.
    engine = ServeEngine(cfg, params, num_slots=NUM_SLOTS,
                         context_len=CONTEXT_LEN, max_new=NEW_MAX,
                         sync_every=1, decode_impl="dense")
    # No prefill_chunk here: chunked admission pays B=1 chunk extends to
    # keep decode responsive under *long* prompts (its exactness has its
    # own tests); at this mix's prompt lengths (<= 24) it is pure
    # overhead and would blur what the pair measures — the fused-window
    # decode path itself.
    fused_engine = ServeEngine(cfg, params, num_slots=NUM_SLOTS,
                               context_len=CONTEXT_LEN, max_new=NEW_MAX,
                               sync_every=8, decode_impl="flash")
    # The paged arm: same fused configuration, same KV bytes as the flat
    # arm (NUM_PAGES pages == NUM_SLOTS full rings), 1.5x the rows (see
    # PAGED_SLOTS above). The prefix cache is off here so the pair
    # isolates paging itself; the shared-prefix win has its own A/B
    # below.
    paged_engine = ServeEngine(cfg, params, num_slots=PAGED_SLOTS,
                               context_len=CONTEXT_LEN, max_new=NEW_MAX,
                               sync_every=8, decode_impl="flash",
                               page_size=PAGE_SIZE, num_pages=NUM_PAGES,
                               prefix_cache=False)
    lockstep = LockstepServer(cfg, params)

    # Warm every shape the arms will see (compile excluded from timing):
    # the full window-K ladder via warmup(), prompt-length prefill shapes
    # via representative submits.
    engine.warmup()
    fused_engine.warmup()
    warm_lens = sorted({ln for m in MIXES.values() for ln, _ in m})
    warm = [engine.submit(rng.integers(0, cfg.vocab_size, ln,
                                       dtype=np.int32), max_new=2)
            for ln in warm_lens]
    while not all(f.done() for f in warm):
        engine.step()
    fwarm = [fused_engine.submit(rng.integers(0, cfg.vocab_size, ln,
                                              dtype=np.int32), max_new=2)
             for ln in warm_lens]
    while not all(f.done() for f in fwarm):
        fused_engine.step()
    paged_engine.warmup()
    pwarm = [paged_engine.submit(rng.integers(0, cfg.vocab_size, ln,
                                              dtype=np.int32), max_new=2)
             for ln in warm_lens]
    while not all(f.done() for f in pwarm):
        paged_engine.step()
    lockstep.submit(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                    2).result(timeout=600)

    step_s = _calibrate_step(engine, rng, cfg.vocab_size)
    emit("serve/step_calibration", step_s * 1e6,
         f"decode step at occupancy {NUM_SLOTS}")

    # Scenario = prompt/budget mix x arrival rate (gaps in step units).
    # 0.25 steps/arrival saturates BOTH pool geometries early in the
    # window (mean service is ~9 steps: an 8-slot pool saturates below
    # 9/8 step gaps, the paged arm's 16 rows below 9/16) — the queue
    # stays non-empty, so tok/s measures scheduling capacity and the
    # paged arm's extra admissible rows are actually exercised; 8.0 is
    # moderate load where latency dominates.
    scenarios = [("mixed", "mixed", 0.25), ("uniform", "uniform", 0.25),
                 ("mixed_slow", "mixed", 8.0),
                 ("longtail", "longtail", 0.25)]
    if smoke:
        scenarios = [("mixed", "mixed", 0.25),
                     ("longtail", "longtail", 0.25)]

    mixed_schedule = None
    cont_mixed_us_tok = None
    engines = {"continuous": engine, "fused": fused_engine,
               "paged": paged_engine}
    for scn, mix_name, gap_steps in scenarios:
        requests = _make_requests(rng, cfg.vocab_size, MIXES[mix_name],
                                  n_req)
        gaps = rng.exponential(gap_steps * step_s, size=n_req)
        if scn == "mixed":
            mixed_schedule = (requests, gaps)   # replayed by the fabric arm

        arms = ("lockstep", "continuous", "fused", "paged")

        def _drive_engine(eng):
            eng.reset_stats()
            pump_stop = threading.Event()
            pump = threading.Thread(
                target=_pump, args=(eng, pump_stop), daemon=True)
            pump.start()
            out = _drive(eng.submit, requests, gaps)
            pump_stop.set()
            pump.join(timeout=10)
            return out, eng.stats()

        # Best of three replays of the same schedule per arm, with the
        # replays INTERLEAVED across arms (A,B,C,D then A,B,C,D again)
        # rather than back-to-back per arm: host load on this busy
        # 2-CPU box drifts over the minutes the scenario takes, and the
        # CI gates compare these rows directly — a paired ratio is only
        # honest if both arms sampled the same host conditions. Within a
        # pair, the drift between adjacent replays is seconds, not
        # minutes; min-per-arm then discards one-sided spikes (two
        # replays proved too few — single-replay spikes of 10-20% on
        # this box flip the gated paged/flat pair run to run).
        replays: dict = {arm: [] for arm in arms}
        for _ in range(3):
            for arm in arms:
                if arm != "lockstep":
                    replays[arm].append(_drive_engine(engines[arm]))
                else:
                    lockstep.reset_stats()
                    replays[arm].append((_drive(lockstep.submit, requests,
                                                gaps),
                                         lockstep.mean_width()))

        for arm in arms:
            (lats, toks, makespan), st = min(
                replays[arm], key=lambda r: r[0][2] / r[0][1])
            occ = st["mean_occupancy"] if arm != "lockstep" else st
            tok_s = toks / makespan
            if arm == "continuous" and scn == "mixed":
                cont_mixed_us_tok = 1e6 * makespan / toks
            extra = (f",slots={PAGED_SLOTS},pages={NUM_PAGES}"
                     if arm == "paged" else "")
            emit(f"serve/{arm}/{scn}/tok", 1e6 * makespan / toks,
                 f"tok_s={tok_s:.1f},occ={occ:.2f},n={n_req}{extra}")
            emit(f"serve/{arm}/{scn}/p50",
                 1e6 * float(np.percentile(lats, 50)),
                 f"{np.percentile(lats, 50)*1e3:.1f}ms")
            emit(f"serve/{arm}/{scn}/p95",
                 1e6 * float(np.percentile(lats, 95)),
                 f"{np.percentile(lats, 95)*1e3:.1f}ms")
            if arm == "fused" and scn == "mixed":
                emit("serve/fused/mixed/syncs_per_tok",
                     st["syncs_per_token"],
                     f"host_syncs={st['host_syncs']} over "
                     f"{st['generated_tokens']} generated tokens "
                     "(CI gates <= 0.25)")

    lockstep.stop()
    engine.stop()
    fused_engine.stop()
    paged_engine.stop()

    # --- shared-prefix reuse A/B (its own engines: longer context) ---
    _run_prefix(emit, cfg, params, rng, smoke)

    # --- the replicated serve fabric (control plane over the engine) ---
    _run_real1(emit, cfg, mixed_schedule, rng)
    _run_scaling(emit, step_s, rng, cfg.vocab_size,
                 target_us_tok=cont_mixed_us_tok)
    _run_telemetry_overhead(emit, step_s, rng, cfg.vocab_size)
    _run_kill(emit, cfg, rng, step_s, n_req=18 if smoke else 30)
    _run_rollout(emit, cfg, rng, step_s, n_req=15 if smoke else 24)


def _pump(engine, stop: threading.Event) -> None:
    """Drive engine.step() until told to stop (idle-waits when empty)."""
    while not stop.is_set():
        if engine.step() == 0:
            time.sleep(0.001)


def _run_prefix(emit, cfg, params, rng, smoke: bool) -> None:
    """Shared-system-prompt A/B: identical paged engines, prefix cache on
    vs off. Every prompt is the SAME 128-token system prefix plus a
    short unique tail (4/8/12 tokens, cycled) with a small decode
    budget — the regime prefix reuse targets: "on" admits warm requests
    by ref-counting the cached prefix pages and prefilling only the
    tail (copy-on-write); "off" re-prefills all ~132-140 prompt tokens
    every time. A full throwaway replay first compiles every shape AND
    populates the cache, so the measured window is the steady state on
    both arms. CI gates prefix_on >= 1.3x prefix_off tok/s."""
    from repro.serve.engine import ServeEngine

    ps = 16
    plen = 8 * ps                        # the shared system prompt
    # Short tails and a tiny decode budget on purpose: both arms pay the
    # tail prefill and the decode identically, so the bigger the shared
    # prefix is relative to them, the more the A/B isolates what the
    # cache actually saves — re-prefilling the 128 shared tokens.
    tails, max_new = (4, 8, 12), 2
    ctx = plen + max(tails) + max_new
    n_req = 9 if smoke else 18
    sys_prompt = rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
    requests = [(np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab_size, tails[i % len(tails)],
                                  dtype=np.int32)]), max_new)
        for i in range(n_req)]
    gaps = rng.exponential(0.002, size=n_req)   # near-saturating arrivals

    def _replay(eng):
        eng.reset_stats()
        stop = threading.Event()
        pump = threading.Thread(target=_pump, args=(eng, stop),
                                daemon=True)
        pump.start()
        out = _drive(eng.submit, requests, gaps)
        stop.set()
        pump.join(timeout=10)
        return out, eng.stats()

    arms = {}
    for arm, on in (("prefix_on", True), ("prefix_off", False)):
        eng = ServeEngine(cfg, params, num_slots=4, context_len=ctx,
                          max_new=max_new, sync_every=8,
                          decode_impl="flash", page_size=ps, num_pages=48,
                          prefix_cache=on)
        eng.warmup()
        _replay(eng)                     # compile shapes + warm the cache
        arms[arm] = eng
    # Interleaved best-of-two, same reasoning as the main arm loop: the
    # CI gate is the on/off ratio, so both arms must sample the same
    # host conditions.
    replays = {arm: [] for arm in arms}
    for _ in range(2):
        for arm, eng in arms.items():
            replays[arm].append(_replay(eng))
    for arm, eng in arms.items():
        (lats, toks, makespan), st = min(
            replays[arm], key=lambda r: r[0][2] / r[0][1])
        pc = st.get("prefix_cache") or {}
        emit(f"serve/{arm}/shared/tok", 1e6 * makespan / toks,
             f"tok_s={toks/makespan:.1f},"
             f"reused_prompt_toks={st['prefix_tokens_reused']},"
             f"hit_rate={pc.get('hit_rate', 0.0):.2f},n={n_req}")
        emit(f"serve/{arm}/shared/p95",
             1e6 * float(np.percentile(lats, 95)),
             f"{np.percentile(lats, 95)*1e3:.1f}ms")
        eng.stop()


# ---- serve fabric arms ------------------------------------------------------

class _PacedEngine:
    """ServeEngine-shaped slotted data plane with a calibrated device time.

    Same admission/occupancy/retirement semantics as the real engine —
    a fixed pool of ``num_slots`` rows, FCFS queue, one token per
    occupied slot per step, immediate retirement at the request's own
    budget — but a decode step costs a fixed ``step_s`` of wall clock
    (host-calibrated from the real engine) instead of XLA compute, and
    each admission charges one extra step (the exact-length prefill).
    This is what a replica backed by its own accelerator looks like to
    the control plane; see the module docstring for why the scaling arm
    needs it on a 2-CPU host.
    """

    def __init__(self, step_s: float, num_slots: int = NUM_SLOTS):
        self._step = step_s
        self._ns = num_slots
        self._q: queue.Queue = queue.Queue()
        self._slots: list = [None] * num_slots   # [prompt, max_new, gen, fut]
        self._free = list(range(num_slots))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, prompt, max_new: int) -> cf.Future:
        fut: cf.Future = cf.Future()
        fut.set_running_or_notify_cancel()
        self._q.put((np.asarray(prompt, np.int32).reshape(-1),
                     int(max_new), fut))
        return fut

    def load(self) -> dict:
        return {"num_slots": self._ns, "free_slots": len(self._free),
                "queue_depth": self._q.qsize(),
                "ewma_us_per_token": self._step * 1e6 / self._ns}

    def _retire(self, i: int) -> None:
        prompt, _, gen, fut = self._slots[i]
        self._slots[i] = None
        self._free.append(i)
        fut.set_result(np.concatenate([prompt,
                                       np.asarray(gen, np.int32)]))

    def _loop(self) -> None:
        # Drift-corrected pacing against a virtual device clock: sleeps
        # on this host overshoot by ~1-2ms (coarse timer granularity),
        # which would silently stretch every "device" step. Advancing a
        # schedule cursor by the charged time and only sleeping while
        # ahead of it makes the *average* step rate exact — an oversleep
        # is repaid by the next iterations running back-to-back (catch-up
        # bounded to two steps, like a device queue that shallow).
        sched = time.perf_counter()
        while not self._stop.is_set():
            admitted = 0
            while self._free:
                try:
                    prompt, mn, fut = self._q.get_nowait()
                except queue.Empty:
                    break
                i = self._free.pop()
                # Prefill emits the first token at admission, like the
                # real engine's exact-length prefill does.
                self._slots[i] = [prompt, mn,
                                  [int(prompt.sum()) % 50021], fut]
                admitted += 1
                if mn <= 1:
                    self._retire(i)
            active = [i for i, s in enumerate(self._slots) if s is not None]
            if not active and not admitted:
                time.sleep(0.0005)
                sched = time.perf_counter()     # idle devices accrue no credit
                continue
            busy = admitted * self._step + (self._step if active else 0.0)
            sched = max(sched + busy,
                        time.perf_counter() - 2.0 * self._step)
            left = sched - time.perf_counter()
            if left > 0:
                time.sleep(left)
            for i in active:
                s = self._slots[i]
                s[2].append((int(s[0].sum()) + len(s[2])) % 50021)
                if len(s[2]) >= s[1]:
                    self._retire(i)

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10)


class _PacedServer:
    """EngineServer-shaped replica over a _PacedEngine (generate blocks
    for one request; load() is the heartbeat's routing signal)."""

    def __init__(self, step_s: float):
        self._engine = _PacedEngine(step_s)

    def generate(self, prompt, max_new=None):
        mn = NEW_MAX if max_new is None else int(max_new)
        return self._engine.submit(prompt, mn).result(timeout=600)

    def load(self):
        return self._engine.load()

    def health(self):
        return {"status": "ok"}

    def stop(self):
        self._engine.stop()


class _Fabric:
    """Registry + inproc-registered replicas + a Router, torn down clean."""

    def __init__(self, servers, prefix: str, ttl_s: float = 1.0,
                 attach_heartbeats: bool = True,
                 queue_slack: int | None = None, coalesce: bool = True):
        self.registry = Registry(ttl_s=ttl_s)
        self._names, self._hbs = [], []
        for i, server in enumerate(servers):
            name = f"{prefix}{i}"
            courier.inprocess.register(name, server)
            self._names.append(name)
            if attach_heartbeats:
                self._hbs.append(Heartbeater(
                    self.registry, name, f"inproc://{name}",
                    load_fn=server.load, period_s=0.1).start())
        self.router = Router(self.registry, refresh_s=0.1,
                             queue_slack=queue_slack, startup_wait_s=10.0,
                             coalesce=coalesce)

    def close(self) -> None:
        self.router.close()
        for hb in self._hbs:
            hb.stop()
        for name in self._names:
            courier.inprocess.unregister(name)


_BACKOFF_RNG = random.Random(11)


def _fabric_submit(router, pool, prompt, max_new) -> cf.Future:
    """Open-loop submit through the router with decorrelated-jitter
    client-side back-off on Overloaded (the fabric's retry-later signal;
    nothing is ever lost). Jitter, not a deterministic schedule: when a
    drain or kill drops capacity, every waiter sees Overloaded at once —
    synchronized resubmits would re-stampede the fabric on the same tick
    (and busy-poll a 2-CPU host)."""
    def task():
        backoff = 0.0
        while True:
            try:
                return router.submit(prompt, max_new)
            except BaseException as exc:  # noqa: BLE001
                if not is_overloaded(exc):
                    raise
                backoff = decorrelated_backoff(backoff, _BACKOFF_RNG,
                                               base_s=0.005, cap_s=0.04)
                time.sleep(backoff)
    return pool.submit(task)


def _run_scaling(emit, step_s: float, rng, vocab: int,
                 target_us_tok: float | None = None,
                 n_req: int = 96) -> None:
    """1/2/4 paced replicas, same seeded arrival schedule (saturating 4).

    The gap scale (a third of a device step) keeps arrivals flowing while
    every pool stays saturated: a single all-at-once burst would freeze
    the least-loaded choice at t=0 and measure join imbalance instead of
    dispatch. n_req stays at 96 even in smoke — the paced arm costs ~2s
    and smaller runs make the makespan tail dominate the ratios.

    ``target_us_tok`` (the PR-4 continuous arm's measured us/token on
    this host) anchors the pacing: a calibration replay of the r1 arm
    rescales the device step once so the 1-replica fabric reproduces the
    real engine's throughput, making r2/r4 honest multiples *of the PR-4
    arm*, not of an arbitrarily paced baseline. (Throughput is linear in
    the step, so one correction lands on target.)
    """
    requests = _make_requests(rng, vocab, MIXES["mixed"], n_req)
    unit_gaps = rng.exponential(1.0, size=n_req)
    attempt_id = [0]

    def once(n_rep: int, step: float, coalesce: bool = True):
        attempt_id[0] += 1
        servers = [_PacedServer(step) for _ in range(n_rep)]
        # Deep queue slack: the scaling arm measures dispatch + replica
        # capacity, so the whole burst queues server-side (FCFS) instead
        # of bouncing off backpressure — Overloaded fail-fast has its own
        # tests and fires in the kill arm's post-kill squeeze.
        fab = _Fabric(servers, prefix=f"fab_r{n_rep}a{attempt_id[0]}_",
                      queue_slack=4 * n_req, coalesce=coalesce)
        pool = cf.ThreadPoolExecutor(max_workers=n_req)
        try:
            lats, toks, makespan = _drive(
                lambda p, mn: _fabric_submit(fab.router, pool, p, mn),
                requests, unit_gaps * (step / 3.0))
            return lats, 1e6 * makespan / toks, fab.router.stats()
        finally:
            pool.shutdown(wait=False)
            fab.close()
            for s in servers:
                s.stop()

    if target_us_tok is not None:
        # One calibration replay of the r1 arm, then rescale the step so
        # the paced single replica reproduces the real engine's tok/s.
        _, cal_us_tok, _ = once(1, step_s)
        step_s *= float(np.clip(target_us_tok / cal_us_tok, 0.25, 4.0))

    base_us = None
    for n_rep in (1, 2, 4):
        # Best of two replays of the same schedule: a host-noise spike
        # mid-window (this is a busy 2-CPU CI box) reads as a fabric
        # regression otherwise.
        lats, us_tok, stats = min((once(n_rep, step_s) for _ in range(2)),
                                  key=lambda r: r[1])
        if n_rep == 1:
            # Router-added latency per request (pick + bookkeeping +
            # courier dispatch), measured where the data plane sleeps
            # instead of fighting the router for the GIL.
            emit("serve/fabric/dispatch", stats["mean_dispatch_us"],
                 f"router admission->dispatch, n={stats['dispatches']}")
            # Paired dispatch A/B: the same r1 run IS the coalesced arm
            # (the router batches concurrent arrivals into one courier
            # frame per replica per drain); one extra replay with the
            # coalescer off prices what per-call RPC dispatch costs.
            emit("serve/fabric/dispatch_coalesced",
                 stats["mean_dispatch_us"],
                 f"mean_calls_per_frame={stats['mean_calls_per_frame']:.2f}"
                 f",frames={stats['frames']},n={stats['dispatches']}")
            _, _, pstats = once(1, step_s, coalesce=False)
            emit("serve/fabric/dispatch_percall",
                 pstats["mean_dispatch_us"],
                 f"one courier call per dispatch,n={pstats['dispatches']}")
        if base_us is None:
            base_us = us_tok
        emit(f"serve/fabric/r{n_rep}/mixed/tok", us_tok,
             f"tok_s={1e6/us_tok:.1f},x={base_us/us_tok:.2f},"
             f"paced_step={step_s*1e6:.0f}us,n={n_req},best_of=2")
        emit(f"serve/fabric/r{n_rep}/mixed/p50",
             1e6 * float(np.percentile(lats, 50)),
             f"{np.percentile(lats, 50)*1e3:.1f}ms")
        emit(f"serve/fabric/r{n_rep}/mixed/p95",
             1e6 * float(np.percentile(lats, 95)),
             f"{np.percentile(lats, 95)*1e3:.1f}ms")


def _run_real1(emit, cfg, schedule, warm_rng) -> None:
    """One REAL engine behind the full fabric on the SAME mixed schedule
    the PR-4 arms replayed: the paired A/B pricing the control plane
    (registry + router dispatch) against serve/continuous.

    The telemetry rows ride on the same setup (the engine is already
    warm, the fabric already up): TTFT percentiles per prefill class
    from the engine's own ``engine.ttft_us.*`` histograms scoped to this
    measured window, and one dedicated end-to-end SAMPLED request whose
    exported spans must account for >= 95% of its measured latency
    (serve/telemetry/coverage — the "explains every microsecond" gate)."""
    from repro.launch.serve import EngineServer
    requests, gaps = schedule
    n_req = len(requests)
    server = EngineServer(cfg, max_new=NEW_MAX, num_slots=NUM_SLOTS,
                          context_len=CONTEXT_LEN)
    fab = _Fabric([server], prefix="fab_real_")
    pool = cf.ThreadPoolExecutor(max_workers=n_req)
    try:
        # Warm every prompt-length shape through the fabric path first
        # (this engine's jit caches are its own — compile excluded here
        # exactly as it is for the PR-4 arms).
        warm = [_fabric_submit(fab.router, pool,
                               warm_rng.integers(0, cfg.vocab_size, ln,
                                                 dtype=np.int32), 2)
                for ln in sorted({ln for ln, _ in MIXES["mixed"]})]
        for f in warm:
            f.result(timeout=600)
        # Scope the TTFT histograms to the measured window: the PR-4/5/7
        # arms above ran engines in this same process, and warmup TTFT
        # includes compile time.
        telemetry.metrics().reset()
        telemetry.spans_buffer().drain()
        lats, toks, makespan = _drive(
            lambda p, mn: _fabric_submit(fab.router, pool, p, mn),
            requests, gaps)
        # One dedicated sampled request through the now-idle fabric: the
        # trace must explain >= 95% of the wall clock the client saw.
        tctx = telemetry.start_trace()
        root_sid = telemetry.new_span_id()
        prompt = warm_rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
        t0w, t0 = time.time(), time.perf_counter()
        with telemetry.activate(tctx.child(root_sid)):
            out = fab.router.submit(prompt, 16)
        e2e = time.perf_counter() - t0
        assert len(out) == len(prompt) + 16
        telemetry.record_span("request", tctx, t0w, e2e,
                              span_id=root_sid, root=True)
        spans = [s for s in telemetry.spans_buffer().drain()
                 if s["trace"] == tctx.trace_id]
        coverage = telemetry.trace_coverage(spans, tctx.trace_id, t0w, e2e)
        hists = telemetry.metrics().snapshot()["histograms"]
    finally:
        pool.shutdown(wait=False)
        fab.close()
        server.kill()
    emit("serve/fabric/real1/mixed/tok", 1e6 * makespan / toks,
         f"tok_s={toks/makespan:.1f},n={n_req},real engine via fabric")
    emit("serve/fabric/real1/mixed/p50",
         1e6 * float(np.percentile(lats, 50)),
         f"{np.percentile(lats, 50)*1e3:.1f}ms")
    emit("serve/fabric/real1/mixed/p95",
         1e6 * float(np.percentile(lats, 95)),
         f"{np.percentile(lats, 95)*1e3:.1f}ms")
    names = sorted({s["name"] for s in spans if not s["attrs"].get("root")})
    emit("serve/telemetry/coverage", coverage,
         f"spans={'+'.join(names)} over {e2e*1e3:.1f}ms e2e "
         "(CI gates >= 0.95)")
    for cls in ("direct", "chunked"):
        snap = hists.get(f"engine.ttft_us.{cls}")
        if not snap or not snap["count"]:
            continue
        h = telemetry.Histogram.from_snapshot(f"engine.ttft_us.{cls}", snap)
        emit(f"serve/telemetry/ttft/{cls}/p50", h.percentile(50),
             f"n={h.count},mean={h.total/h.count:.0f}us")
        emit(f"serve/telemetry/ttft/{cls}/p95", h.percentile(95),
             f"n={h.count},max={h.vmax:.0f}us")


def _run_telemetry_overhead(emit, step_s: float, rng, vocab: int,
                            n_req: int = 96) -> None:
    """Paired telemetry-on vs telemetry-off A/B on the SAME seeded mixed
    schedule (CI gates on <= 1.03x off us/token).

    The replica is paced for the same reason the scaling arm's is: the
    claim under test is that tracing adds nothing to the *serving path*
    (client mint + envelope inject/extract + span records in the router
    and replica), and co-locating real XLA with the router on a 2-CPU
    host would drown that signal in GIL/core contention noise. The ON
    arm samples EVERY request — each one mints a trace, rides the
    courier envelope through router dispatch, and records the full span
    set — which upper-bounds any production trace_every>=1 setting.
    Interleaved best-of-3 per arm, min us/token, same discipline as the
    other gated pairs on this box."""
    requests = _make_requests(rng, vocab, MIXES["mixed"], n_req)
    unit_gaps = rng.exponential(1.0, size=n_req)
    attempt_id = [0]

    def once(traced: bool) -> float:
        attempt_id[0] += 1
        servers = [_PacedServer(step_s)]
        fab = _Fabric(servers, prefix=f"fab_tel{attempt_id[0]}_",
                      queue_slack=4 * n_req)
        pool = cf.ThreadPoolExecutor(max_workers=n_req)
        telemetry.metrics().reset()
        telemetry.spans_buffer().drain()

        def submit(p, mn):
            if not traced:
                return _fabric_submit(fab.router, pool, p, mn)
            # Mint on the caller (as a client would), activate inside the
            # pool task: contextvars don't cross ThreadPoolExecutor.
            tctx = telemetry.start_trace()
            root_sid = telemetry.new_span_id()

            def task():
                t0w, t0 = time.time(), time.perf_counter()
                with telemetry.activate(tctx.child(root_sid)):
                    backoff = 0.0
                    while True:
                        try:
                            out = fab.router.submit(p, mn)
                            break
                        except BaseException as exc:  # noqa: BLE001
                            if not is_overloaded(exc):
                                raise
                            backoff = decorrelated_backoff(
                                backoff, _BACKOFF_RNG,
                                base_s=0.005, cap_s=0.04)
                            time.sleep(backoff)
                telemetry.record_span("request", tctx, t0w,
                                      time.perf_counter() - t0,
                                      span_id=root_sid, root=True)
                return out
            return pool.submit(task)

        try:
            _, toks, makespan = _drive(submit, requests,
                                       unit_gaps * (step_s / 3.0))
            return 1e6 * makespan / toks
        finally:
            pool.shutdown(wait=False)
            fab.close()
            for s in servers:
                s.stop()
            telemetry.spans_buffer().drain()

    # Four interleaved replays per arm, alternating order so a slow
    # drift in host load cancels instead of landing on one arm; min per
    # arm converges both to their quiet-window floor, where the true
    # (sub-1%) tracing cost is the only difference left.
    offs, ons = [], []
    for i in range(4):
        for traced in ((False, True) if i % 2 == 0 else (True, False)):
            (ons if traced else offs).append(once(traced))
    off_us, on_us = min(offs), min(ons)
    emit("serve/telemetry_off/mixed/tok", off_us,
         f"tok_s={1e6/off_us:.1f},n={n_req},best_of=4,untraced")
    emit("serve/telemetry_on/mixed/tok", on_us,
         f"tok_s={1e6/on_us:.1f},n={n_req},best_of=4,trace_every=1")
    emit("serve/telemetry/overhead_x", on_us / off_us,
         f"on={on_us:.1f} off={off_us:.1f} us/tok (CI gates <= 1.03)")


def _run_kill(emit, cfg, rng, step_s: float, n_req: int) -> None:
    """Two REAL engines; replica 0 is killed mid-run (a count-triggered
    ``FaultInjector`` event — the same schedule machinery the chaos demo
    and the rollout arm use). In-flight requests must fail over to the
    sibling: the gate is zero lost."""
    from repro.core.fault import FaultEvent, FaultInjector
    from repro.launch.serve import EngineServer
    fab_names = [f"fab_kill_{i}" for i in range(2)]
    registry = Registry(ttl_s=1.0)
    servers = []
    for name in fab_names:
        # The replicas own their heartbeats (registry= wiring), so kill()
        # silences the beats the way a real crash does.
        server = EngineServer(cfg, max_new=NEW_MAX, num_slots=NUM_SLOTS,
                              context_len=CONTEXT_LEN, registry=registry,
                              heartbeat_s=0.1, name=name,
                              endpoint=f"inproc://{name}")
        courier.inprocess.register(name, server)
        servers.append(server)
    router = Router(registry, refresh_s=0.1, queue_slack=4,
                    startup_wait_s=10.0)
    pool = cf.ThreadPoolExecutor(max_workers=n_req)
    try:
        # Warm every prompt-length shape on BOTH replicas directly (the
        # router spreads by load, so routing the warmup can leave one
        # replica to compile a shape mid-measurement — observed as a
        # multi-second "recovery" that was really jit compile).
        for ln in sorted({ln for ln, _ in MIXES["mixed"]}):
            prompt = rng.integers(0, cfg.vocab_size, ln, dtype=np.int32)
            for server in servers:
                server.generate(prompt, max_new=2)
        requests = _make_requests(rng, cfg.vocab_size, MIXES["mixed"], n_req)
        # Moderate load: the sibling must absorb the dead replica's share.
        gaps = rng.exponential(2.0 * step_s, size=n_req)
        # Count-triggered crash: beats stop, engine dies, deterministically
        # mid-run (after a third of the requests have COMPLETED — the
        # router's stats() is the injector's progress source).
        injector = FaultInjector(
            [FaultEvent(kind="kill", target=0, after_served=n_req // 3)],
            [servers[0]], progress=[router])
        futs = []
        t_kill = None
        t_next = time.perf_counter()
        for (p, mn), gap in zip(requests, gaps):
            now = time.perf_counter()
            if now < t_next:
                time.sleep(t_next - now)
            t_sub = time.perf_counter()
            futs.append(_fabric_submit(router, pool, p, mn))
            t_next = t_sub + gap
            if t_kill is None and injector.poll() == 0:
                t_kill = time.perf_counter()
        while t_kill is None:             # completions lag submissions
            if injector.poll() == 0:
                t_kill = time.perf_counter()
            else:
                time.sleep(0.002)
        lost = 0
        for fut in futs:
            try:
                fut.result(timeout=600)
            except BaseException:  # noqa: BLE001 - a lost request
                lost += 1
        stats = router.stats()
        # Recovery = kill -> the first completion that actually failed
        # over (router-attributed; a sibling-served request finishing
        # right after the kill must not masquerade as recovery). A run
        # where nothing failed over emits the -1 sentinel — CI gates on
        # failovers >= 1, so the degenerate run fails loudly instead of
        # reading as a perfect 0ms recovery.
        done_s = stats["first_failover_done_s"]
        recovery_s = max(0.0, done_s - t_kill) if done_s is not None else -1e-6
    finally:
        pool.shutdown(wait=False)
        router.close()
        for server in servers:
            server.kill()                 # idempotent for the dead one
        for name in fab_names:
            courier.inprocess.unregister(name)
    emit("serve/fabric/kill/lost", float(lost),
         f"failovers={stats['failovers']},retries={stats['retries']},"
         f"n={n_req}")
    emit("serve/fabric/kill/failovers", float(stats["failovers"]),
         "requests retried onto the sibling (CI gates >= 1)")
    emit("serve/fabric/kill/recovery", recovery_s * 1e6,
         f"{recovery_s*1e3:.1f}ms to first failed-over completion"
         if recovery_s >= 0 else "SENTINEL: no failover exercised")


def _run_rollout(emit, cfg, rng, step_s: float, n_req: int) -> None:
    """Zero-downtime weight rollout under live traffic, three chaos
    phases over the SAME 2-replica fleet of REAL store-backed engines:

      1. **bad version** — roll toward a version published with the wrong
         parameter shapes. The swap's ``restore(like=...)`` health gate
         rejects it before any weight installs; the controller rolls the
         fleet back. Gates: ``rollback_ok == 1`` (status rolled_back AND
         every replica still serves v0), ``rollback_lost == 0``.
      2. **happy path** — roll v0 -> v1 with the canary gate on. A
         sampler thread watches ``router.health()["dispatchable"]``
         throughout. Gates: ``lost == 0``, ``min_dispatchable >= 1``
         (the fleet never drops below N-1 during the roll). Rows also
         report the availability dip duration, time-to-full-rollout, and
         the canary-vs-baseline us/token pair from the router's
         per-version meters.
      3. **mid-drain kill** — roll back v1 -> v0, with a FaultInjector
         predicate that crashes replica 0 the moment the registry marks
         it draining. The controller must detect the death (TTL
         eviction), skip it, and finish the roll on the sibling.
         Gate: ``lost == 0``.
    """
    import tempfile

    import jax

    from repro.ckpt.checkpoint import ModelStore, config_hash
    from repro.core.fault import FaultEvent, FaultInjector
    from repro.launch.serve import EngineServer
    from repro.models import transformer
    from repro.serve.rollout import RolloutController

    store_dir = tempfile.mkdtemp(prefix="rollout_store-")
    store = ModelStore(store_dir)
    params = transformer.init_params(cfg, jax.random.key(0))
    for v in (0, 1):
        store.publish_version(
            v, transformer.init_params(cfg, jax.random.key(v)),
            metadata={"step": v, "config_hash": config_hash(cfg)})
    # Version 9: right tree structure, wrong leaf shapes — what a version
    # published for a different architecture looks like. The swap gate
    # (restore against the live tree) must reject it on the first replica.
    store.publish_version(
        9, jax.tree.map(lambda x: np.zeros((np.asarray(x).size + 1,),
                                           np.asarray(x).dtype), params),
        metadata={"step": 9, "config_hash": "wrong-arch"})

    names = [f"fab_roll_{i}" for i in range(2)]
    registry = Registry(ttl_s=1.0)
    servers = []
    for name in names:
        server = EngineServer(cfg, max_new=NEW_MAX, num_slots=NUM_SLOTS,
                              context_len=CONTEXT_LEN, registry=registry,
                              heartbeat_s=0.1, name=name,
                              endpoint=f"inproc://{name}",
                              store_dir=store_dir, version=0)
        courier.inprocess.register(name, server)
        servers.append(server)
    router = Router(registry, refresh_s=0.05, queue_slack=4,
                    startup_wait_s=10.0)
    controller = RolloutController(
        registry, [router], drain_timeout_s=60.0, poll_s=0.005,
        canary_fraction=0.25, canary_requests=4, canary_timeout_s=60.0)
    pool = cf.ThreadPoolExecutor(max_workers=4 * n_req)

    samples: list[tuple[float, int]] = []
    sampler_stop = threading.Event()

    def _sample():
        while not sampler_stop.is_set():
            try:
                samples.append((time.perf_counter(),
                                int(router.health()["dispatchable"])))
            except BaseException:  # noqa: BLE001 - router mid-teardown
                pass
            time.sleep(0.005)

    def _traffic(n):
        """Paced open-loop submissions; returns the request futures."""
        reqs = _make_requests(rng, cfg.vocab_size, MIXES["mixed"], n)
        gaps = rng.exponential(2.0 * step_s, size=n)
        futs = []
        t_next = time.perf_counter()
        for (p, mn), gap in zip(reqs, gaps):
            now = time.perf_counter()
            if now < t_next:
                time.sleep(t_next - now)
            futs.append(_fabric_submit(router, pool, p, mn))
            t_next = time.perf_counter() + gap
        return futs

    def _phase(target, injector=None):
        """Run a rollout with traffic flowing; returns (result, lost)."""
        futs: list = []
        done = threading.Event()

        def _pump_traffic():
            while not done.is_set():
                futs.extend(_traffic(n_req))

        traffic = threading.Thread(target=_pump_traffic, daemon=True)
        inj_stop = threading.Event()
        inj = None
        if injector is not None:
            def _pump_inj():
                while not inj_stop.is_set() and injector.poll():
                    time.sleep(0.001)
            inj = threading.Thread(target=_pump_inj, daemon=True)
        traffic.start()
        if inj is not None:
            inj.start()
        try:
            result = controller.rollout(target)
        finally:
            done.set()
            traffic.join(timeout=600)
            inj_stop.set()
            if inj is not None:
                inj.join(timeout=10)
        lost = 0
        for fut in futs:
            try:
                fut.result(timeout=600)
            except BaseException:  # noqa: BLE001 - a lost request
                lost += 1
        return result, lost

    try:
        # Warm every prompt-length shape on BOTH replicas directly (see
        # _run_kill: routed warmup can leave a shape to compile mid-roll).
        for ln in sorted({ln for ln, _ in MIXES["mixed"]}):
            prompt = rng.integers(0, cfg.vocab_size, ln, dtype=np.int32)
            for server in servers:
                server.generate(prompt, max_new=2)
        sampler = threading.Thread(target=_sample, daemon=True)
        sampler.start()

        # Phase 1: bad version -> fleet-wide rollback, nothing lost.
        bad_result, bad_lost = _phase(9)
        rollback_ok = float(bad_result["status"] == "rolled_back"
                            and all(s.load().get("version") == 0
                                    for s in servers))

        # Phase 2: happy v0 -> v1 with the canary gate, sampled.
        t_roll0 = time.perf_counter()
        result, lost = _phase(1)
        t_roll1 = time.perf_counter()
        promoted = float(result["status"] == "promoted"
                         and all(s.load().get("version") == 1
                                 for s in servers))
        window = [(t, d) for t, d in samples if t_roll0 <= t <= t_roll1]
        min_disp = min((d for _, d in window), default=-1)
        dip_s = 0.0
        for (t_a, d_a), (t_b, _) in zip(window, window[1:]):
            if d_a < len(servers):
                dip_s += t_b - t_a

        # Phase 3: roll back v1 -> v0 with a kill the moment replica 0
        # starts draining (the chaos case the drain mark must survive).
        injector = FaultInjector(
            [FaultEvent(
                kind="kill", target=0,
                when=lambda: registry.version_table()
                                     .get(names[0], {})
                                     .get("draining", False))],
            [servers[0]])
        kill_result, kill_lost = _phase(0, injector=injector)
    finally:
        sampler_stop.set()
        pool.shutdown(wait=False)
        router.close()
        for server in servers:
            server.kill()
        for name in names:
            courier.inprocess.unregister(name)

    per_version = router.stats().get("per_version", {})
    emit("serve/rollout/rollback_ok", rollback_ok,
         f"bad-version roll -> {bad_result['status']} "
         f"({bad_result.get('reason')}); fleet back on v0 (CI gates == 1)")
    emit("serve/rollout/rollback_lost", float(bad_lost),
         "requests lost during the bad-version rollback (CI gates == 0)")
    emit("serve/rollout/lost", float(lost),
         f"requests lost during the v0->v1 roll, promoted={promoted:.0f} "
         "(CI gates == 0)")
    emit("serve/rollout/min_dispatchable", float(min_disp),
         f"sampled every 5ms across the roll, n={len(window)} "
         "(CI gates >= 1: never below N-1)")
    emit("serve/rollout/dip_s", dip_s * 1e6,
         f"{dip_s*1e3:.1f}ms total below full dispatchable capacity")
    emit("serve/rollout/time_to_full", result["duration_s"] * 1e6,
         f"{result['duration_s']:.2f}s drain->swap->canary->promote, "
         f"canary={'ok' if (result.get('canary') or {}).get('ok') else '-'}")
    for label, key in (("canary_tok", "1"), ("baseline_tok", "0")):
        row = per_version.get(key)
        if row and row["completed"]:
            emit(f"serve/rollout/{label}", row["us_per_token"],
                 f"v{key}: n={row['completed']},"
                 f"p50={row['p50_lat_us']/1e3:.1f}ms")
    emit("serve/rollout/middrain/lost", float(kill_lost),
         f"kill at drain-start -> {kill_result['status']}, "
         f"replicas={kill_result.get('replicas')} (CI gates == 0)")


if __name__ == "__main__":
    def _print(name, us, derived=""):
        print(f"{name},{us:.2f},{derived}")
    run(_print)
