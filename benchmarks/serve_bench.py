"""End-to-end serve benchmark: continuous batching vs the lockstep baseline.

Paired A/B of the two serving policies over the SAME model, request
stream, and arrival schedule — the only variable is iteration-level
scheduling:

  * ``lockstep``: static-shape batching, the strongest simple baseline on
    a recompile-happy backend — a coalescing worker drains up to
    ``MAX_BATCH`` queued prompts (or waits ``MAX_WAIT_S``), pads the
    group to a fixed ``(MAX_BATCH, S_max)`` shape (one jit executable,
    zero mid-run recompiles), and runs prefill plus ``max(max_new in
    group)`` decode steps once per batch: every request waits for its
    batch boundary, and the whole batch waits for its slowest member.
    Ragged rows use the length mask, so the comparison is
    correctness-for-correctness.
  * ``continuous``: :class:`repro.serve.engine.ServeEngine` — arrivals
    admitted into free KV-cache slots between decode steps (exact-length
    prefill), sequences retire their slot the moment their own budget is
    done, replies stream back per request.

Requests mix prompt lengths AND decode budgets (real traffic stops at
EOS at different depths); that mix is precisely what lockstep cannot
exploit — a 4-token request pinned in a batch with a 16-token one holds
its slot for 16 steps. Arrival schedules are seeded pseudo-Poisson,
calibrated against the measured decode-step time of this host so "heavy"
means the same relative load everywhere. Warmup requests run every jit
shape before the measured window; compile time is excluded from both
arms.

Rows (us_per_call column):
  serve/{arm}/{scenario}/tok — microseconds per *generated* token
                               (derived: tok_s, mean slot occupancy)
  serve/{arm}/{scenario}/p50 — per-request latency p50, microseconds
  serve/{arm}/{scenario}/p95 — per-request latency p95, microseconds

``REPRO_SMOKE=1`` shrinks to the CI-gated "mixed" scenario with fewer
requests. CI gates: continuous us/tok < lockstep us/tok AND continuous
p95 <= 1.05 * lockstep p95 at "mixed".
"""

from __future__ import annotations

import os
import queue
import threading
import time
from concurrent import futures as cf

import numpy as np

MAX_BATCH = 8
MAX_WAIT_S = 0.02
NUM_SLOTS = 8

# (prompt_len, max_new) cycled per request. Budgets deliberately do not
# track lengths, like EOS depth in real traffic.
MIXES = {
    "mixed": ((4, 16), (12, 4), (24, 8), (8, 12)),
    "uniform": ((8, 8),),
}
S_MAX = max(ln for m in MIXES.values() for ln, _ in m)
NEW_MAX = max(mn for m in MIXES.values() for _, mn in m)
CONTEXT_LEN = S_MAX + NEW_MAX


def _smoke() -> bool:
    return os.environ.get("REPRO_SMOKE", "") == "1"


class LockstepServer:
    """In-process mirror of the lockstep Batcher+ModelServer pair.

    One jit executable: every batch is padded to (max_batch, s_max) —
    short groups carry dummy rows, short prompts carry pad tokens (masked
    by ``lengths``) — and decodes for the *largest* budget in the group.
    ``submit`` returns a Future resolving to the request's own
    [len + max_new] sequence.
    """

    def __init__(self, cfg, params, *, max_batch: int = MAX_BATCH,
                 max_wait_s: float = MAX_WAIT_S):
        self._cfg, self._params = cfg, params
        self._max_batch, self._max_wait = max_batch, max_wait_s
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._widths: list[int] = []
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, prompt, max_new: int) -> cf.Future:
        fut: cf.Future = cf.Future()
        fut.set_running_or_notify_cancel()
        self._q.put((np.asarray(prompt, np.int32), int(max_new), fut))
        return fut

    def _generate(self, batch, lengths, steps):
        import jax.numpy as jnp
        from repro.serve import decode as serve_lib
        # context_len is pinned to the worst case so prefill+step keep ONE
        # compiled shape; ``steps`` only changes the python loop length.
        return np.asarray(serve_lib.generate(
            self._cfg, self._params, jnp.asarray(batch), max_new=steps,
            context_len=CONTEXT_LEN, lengths=lengths))

    def _loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            group = [first]
            deadline = time.monotonic() + self._max_wait
            while len(group) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    group.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            batch = np.zeros((self._max_batch, S_MAX), np.int32)
            lengths = np.full((self._max_batch,), S_MAX, np.int32)
            for row, (p, _, _) in enumerate(group):
                batch[row, :len(p)] = p
                lengths[row] = len(p)
            steps = max(mn for _, mn, _ in group)   # slowest member rules
            self._widths.append(len(group))
            try:
                out = self._generate(batch, lengths, steps)
            except BaseException as exc:  # noqa: BLE001
                for _, _, fut in group:
                    fut.set_exception(exc)
                continue
            for row, (p, mn, fut) in enumerate(group):
                fut.set_result(out[row, :len(p) + mn])

    def mean_width(self) -> float:
        return float(np.mean(self._widths)) if self._widths else 0.0

    def reset_stats(self) -> None:
        self._widths.clear()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10)


def _drive(submit, requests, gaps):
    """Replay an arrival schedule against ``submit(prompt, max_new)``;
    returns (latencies_s, new_tokens_total, makespan_s)."""
    lock = threading.Lock()
    lats: list[float] = []
    done_at = [0.0]

    def track(fut, t_arr):
        def _cb(f):
            now = time.perf_counter()
            with lock:
                lats.append(now - t_arr)
                done_at[0] = max(done_at[0], now)
        fut.add_done_callback(_cb)

    futs = []
    t_start = time.perf_counter()
    t_next = t_start
    for (p, mn), gap in zip(requests, gaps):
        now = time.perf_counter()
        if now < t_next:
            time.sleep(t_next - now)
        t_arr = time.perf_counter()
        fut = submit(p, mn)
        track(fut, t_arr)
        futs.append(fut)
        t_next = t_arr + gap
    new_tokens = 0
    for (p, _), f in zip(requests, futs):
        new_tokens += len(f.result(timeout=600)) - len(p)
    return np.array(lats), new_tokens, done_at[0] - t_start


def _calibrate_step(engine, rng, vocab, n_steps: int = 20) -> float:
    """Median decode-step seconds at full occupancy (engine pre-warmed)."""
    for _ in range(engine.num_slots):
        engine.submit(rng.integers(0, vocab, 8, dtype=np.int32),
                      max_new=n_steps + 4)
    times = []
    while engine.stats()["free_slots"] > 0 or len(times) < n_steps:
        t0 = time.perf_counter()
        if engine.step() == 0:
            break
        times.append(time.perf_counter() - t0)
    while engine.step():
        pass                                    # drain
    return float(np.median(times))


def _make_requests(rng, vocab, mix, n_req):
    return [(rng.integers(0, vocab, mix[i % len(mix)][0], dtype=np.int32),
             mix[i % len(mix)][1]) for i in range(n_req)]


def run(emit) -> None:
    import jax
    from repro import configs
    from repro.models import transformer
    from repro.serve.engine import ServeEngine

    smoke = _smoke()
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    n_req = 24 if smoke else 48

    # One engine for every scenario: its jit caches are the warmup.
    engine = ServeEngine(cfg, params, num_slots=NUM_SLOTS,
                         context_len=CONTEXT_LEN, max_new=NEW_MAX)
    lockstep = LockstepServer(cfg, params)

    # Warm every shape both arms will see (compile excluded from timing).
    warm_lens = sorted({ln for m in MIXES.values() for ln, _ in m})
    warm = [engine.submit(rng.integers(0, cfg.vocab_size, ln,
                                       dtype=np.int32), max_new=2)
            for ln in warm_lens]
    while not all(f.done() for f in warm):
        engine.step()
    lockstep.submit(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                    2).result(timeout=600)

    step_s = _calibrate_step(engine, rng, cfg.vocab_size)
    emit("serve/step_calibration", step_s * 1e6,
         f"decode step at occupancy {NUM_SLOTS}")

    # Scenario = prompt/budget mix x arrival rate (gaps in step units).
    # 1.0 steps/arrival saturates an 8-slot pool whose mean service is
    # ~9 steps: the queue stays non-empty, so tok/s measures scheduling
    # capacity; 8.0 is moderate load where latency dominates.
    scenarios = [("mixed", "mixed", 1.0), ("uniform", "uniform", 1.0),
                 ("mixed_slow", "mixed", 8.0)]
    if smoke:
        scenarios = [("mixed", "mixed", 1.0)]

    for scn, mix_name, gap_steps in scenarios:
        requests = _make_requests(rng, cfg.vocab_size, MIXES[mix_name],
                                  n_req)
        gaps = rng.exponential(gap_steps * step_s, size=n_req)

        for arm in ("lockstep", "continuous"):
            if arm == "continuous":
                engine.reset_stats()
                pump_stop = threading.Event()
                pump = threading.Thread(
                    target=_pump, args=(engine, pump_stop), daemon=True)
                pump.start()
                lats, toks, makespan = _drive(engine.submit, requests, gaps)
                pump_stop.set()
                pump.join(timeout=10)
                occ = engine.stats()["mean_occupancy"]
            else:
                lockstep.reset_stats()
                lats, toks, makespan = _drive(lockstep.submit, requests,
                                              gaps)
                occ = lockstep.mean_width()
            tok_s = toks / makespan
            emit(f"serve/{arm}/{scn}/tok", 1e6 * makespan / toks,
                 f"tok_s={tok_s:.1f},occ={occ:.2f},n={n_req}")
            emit(f"serve/{arm}/{scn}/p50",
                 1e6 * float(np.percentile(lats, 50)),
                 f"{np.percentile(lats, 50)*1e3:.1f}ms")
            emit(f"serve/{arm}/{scn}/p95",
                 1e6 * float(np.percentile(lats, 95)),
                 f"{np.percentile(lats, 95)*1e3:.1f}ms")

    lockstep.stop()
    engine.stop()


def _pump(engine, stop: threading.Event) -> None:
    """Drive engine.step() until told to stop (idle-waits when empty)."""
    while not stop.is_set():
        if engine.step() == 0:
            time.sleep(0.001)


if __name__ == "__main__":
    def _print(name, us, derived=""):
        print(f"{name},{us:.2f},{derived}")
    run(_print)
