"""Kernel micro-bench: wall time of the interpret-mode kernels vs their
jnp oracles on small shapes. Interpret-mode timings are NOT TPU
performance (the kernel body runs as python/XLA ops); the derived column
reports the analytic HBM bytes each kernel moves on TPU — the quantity
the roofline model uses.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _t(fn, n=3):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(emit):
    key = jax.random.key(0)
    B, S, H, KV, dh = 1, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.bfloat16)
    flash_bytes = 2 * (q.size + 2 * k.size + q.size)  # q,k,v,o one pass
    emit("kernel/flash_attention/interp",
         _t(lambda: ops.flash_attention(q, k, v, interpret=True)),
         f"tpu_hbm_bytes={flash_bytes}")
    emit("kernel/flash_attention/ref",
         _t(lambda: ref.flash_attention(q, k, v)),
         f"xla_extra_bytes~={4 * B * H * S * S}")

    L = 2048
    qd = jax.random.normal(ks[0], (B, H, dh), jnp.bfloat16)
    kd = jax.random.normal(ks[1], (B, L, KV, dh), jnp.bfloat16)
    vd = jax.random.normal(ks[2], (B, L, KV, dh), jnp.bfloat16)
    valid = jnp.ones((B, L), bool)
    emit("kernel/decode_attention/interp",
         _t(lambda: ops.decode_attention(qd, kd, vd, valid, interpret=True)),
         f"tpu_hbm_bytes={2 * 2 * kd.size}")

    # Paired decode arms at a serving-like shape: the dense mirror of the
    # engine's decode-attention inner loop (materialized [B,H,L] scores)
    # vs the ops dispatcher exactly as models/attention.py calls it
    # (Pallas on TPU, jit'd oracle elsewhere — real executables both, so
    # the pair is comparable on any backend, unlike the interpret row).
    Bd, Ld = 8, 2048
    qd = jax.random.normal(ks[0], (Bd, H, dh), jnp.bfloat16)
    kd = jax.random.normal(ks[1], (Bd, Ld, KV, dh), jnp.bfloat16)
    vd = jax.random.normal(ks[2], (Bd, Ld, KV, dh), jnp.bfloat16)
    valid = (jnp.arange(Ld)[None, :]
             < jnp.linspace(Ld // 2, Ld, Bd, dtype=jnp.int32)[:, None])

    @jax.jit
    def _dense(q, k, v, m):
        g = H // KV
        kh = jnp.repeat(k, g, axis=2).astype(jnp.float32)
        vh = jnp.repeat(v, g, axis=2)
        s = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32), kh)
        s = s * (dh ** -0.5) + jnp.where(m[:, None], 0.0, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhl,blhd->bhd", w, vh.astype(jnp.float32))

    emit("kernel/decode_attention/dense",
         _t(lambda: _dense(qd, kd, vd, valid), n=10),
         f"B={Bd} L={Ld} scores_bytes={4 * Bd * H * Ld}")
    emit("kernel/decode_attention/flash",
         _t(lambda: ops.decode_attention(qd, kd, vd, valid), n=10),
         f"B={Bd} L={Ld} tpu_hbm_bytes={2 * 2 * kd.size}")

    W = 256
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.9, 0.999)
    x = jax.random.normal(ks[1], (B, S, W), jnp.float32)
    h0 = jnp.zeros((B, W), jnp.float32)
    emit("kernel/rglru_scan/interp",
         _t(lambda: ops.rglru_scan(a, x, h0, interpret=True)),
         f"tpu_hbm_bytes={4 * 3 * a.size}")

    Di, N = 256, 16
    u = jax.random.normal(ks[0], (B, S, Di), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.5)
    Bc = jax.random.normal(ks[0], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[1], (B, S, N), jnp.float32)
    D = jnp.ones((Di,), jnp.float32)
    hs = jnp.zeros((B, Di, N), jnp.float32)
    # XLA associative scan materializes [B,S,Di,N] fp32 twice; kernel never.
    emit("kernel/ssm_scan/interp",
         _t(lambda: ops.ssm_scan(u, delta, A, Bc, Cc, D, hs, interpret=True)),
         f"xla_extra_bytes~={2 * 4 * B * S * Di * N}")
