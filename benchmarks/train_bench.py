"""Elastic training-fabric benchmark: worker churn with bounded step loss.

Paired arms over the SAME toy task (4->16->1 tanh MLP regression on a
fixed target function), the same fleet shape (replay + 2 actors +
learners), and the same step budget — the only variable is the fault
schedule:

  * ``baseline``       — static 2-learner fleet, no faults. The loss and
      wall-clock reference the chaos arms are paired against.
  * ``kill_actor``     — one actor is killed mid-run. Actors are
      stateless (paper §6): the supervisor respawns it, the learner set
      never blinks, and the gate is ZERO lost steps (the chief is never
      restored, so its start_step stays 0).
  * ``kill_learner``   — the CHIEF learner is killed mid-run. The
      respawned chief restores the latest *published* ModelStore version,
      so the gate is step loss <= the publish interval (steps lost =
      step at kill - restored start step).
  * ``elastic_shrink`` — the learner set is resized 2 -> 1 mid-run
      (graceful retire). Training continues on the survivor; the final
      loss is paired against baseline in the derived column.
  * ``elastic_grow``   — 1 -> 2 mid-run: the grown learner restores the
      latest published version in its ctor and joins the quorum.
  * ``compressed``     — baseline fleet forced onto the int8
      error-feedback gradient wire format (the >= 4x wire shrink path
      big models select by size); pairs loss against dense baseline.

Rows (us_per_call column):
  train/{arm}/step        — wall-clock microseconds per training step
                            (includes spawn + jit; arms pay it equally)
  train/{arm}/final_loss  — chief's loss at the last step (x1e6 scale is
                            not applied: the value IS the loss)
  train/kill_actor/lost_steps    — CI gates == 0
  train/kill_learner/step_loss   — CI gates <= publish_every
  train/kill_learner/recovery_s  — kill -> respawned chief live, seconds

``REPRO_SMOKE=1`` halves the step budget. A timed-out arm emits the -1
sentinel in its gate row so CI fails loudly instead of reading a hung
fleet as a perfect run.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

SMOKE = os.environ.get("REPRO_SMOKE", "") == "1"


def _target(x):
    return np.sin(x[:, 0]) + 0.5 * x[:, 1] - 0.2 * x[:, 2] * x[:, 3]


def _rollout(params, rng):
    x = rng.normal(size=(8, 4)).astype(np.float32)
    return {"x": x, "y": _target(x).astype(np.float32)}


class _Fleet:
    """One in-process training fabric: registry + replay + actors +
    learners on a ThreadWorkerSpawner, driven by polling the supervisor
    (the bench owns the loop so chaos events can fire at exact steps)."""

    def __init__(self, *, learners: int, actors: int, total_steps: int,
                 publish_every: int, strategy: str = "auto"):
        import jax
        import jax.numpy as jnp
        from repro.core.discovery import Registry
        from repro.core.fault import RestartPolicy
        from repro.data.replay import TableConfig
        from repro.train import fabric
        from repro.train.optimizer import OptimizerConfig

        class ToyTask:
            optimizer = OptimizerConfig(lr=0.03, warmup_steps=0,
                                        total_steps=1_000_000,
                                        weight_decay=0.0, clip_norm=None)

            def init_params(self, key):
                k1, k2 = jax.random.split(key)
                return {"w1": jax.random.normal(k1, (4, 16)) * 0.5,
                        "b1": jnp.zeros((16,)),
                        "w2": jax.random.normal(k2, (16, 1)) * 0.5,
                        "b2": jnp.zeros((1,))}

            def grad_fn(self, params, batch):
                def loss(p):
                    h = jnp.tanh(batch["x"] @ p["w1"] + p["b1"])
                    pred = (h @ p["w2"] + p["b2"])[:, 0]
                    return jnp.mean((pred - batch["y"]) ** 2)
                return jax.value_and_grad(loss)(params)

            def collate(self, items):
                return {"x": np.concatenate([it["x"] for it in items]),
                        "y": np.concatenate([it["y"] for it in items])}

        self._fabric = fabric
        self.store_dir = tempfile.mkdtemp(prefix="train_bench-")
        self.registry = Registry(ttl_s=1.0)
        self.spawner = fabric.ThreadWorkerSpawner()
        self.cfg = fabric.FabricConfig(
            total_steps=total_steps, batch_size=4,
            publish_every=publish_every, grad_strategy=strategy,
            peer_timeout_s=5.0, heartbeat_s=0.1, insert_timeout_s=0.5,
            sample_timeout_s=0.5)
        task = ToyTask()
        table = TableConfig(name="batches", max_size=500,
                            min_size_to_sample=8, samples_per_insert=4.0,
                            spi_tolerance=8.0)
        resolver = fabric.registry_resolver(self.registry, "replay")
        cfg, registry, spawner = self.cfg, self.registry, self.spawner
        store_dir = self.store_dir

        def spawn_fn(name):
            role, idx = name.rsplit("-", 1)
            if role == "replay":
                spawner.spawn(name, lambda n, ep: fabric.ReplayService(
                    [table], registry, name=n, endpoint=ep,
                    heartbeat_s=cfg.heartbeat_s))
            elif role == "learner":
                batch_fn = fabric.replay_batch_fn(
                    resolver, "batches", task.collate, cfg.batch_size,
                    cfg.sample_timeout_s)
                spawner.spawn(name, lambda n, ep, i=int(idx):
                              fabric.LearnerWorker(
                                  task, batch_fn, store_dir, registry, cfg,
                                  name=n, chief=(i == 0), endpoint=ep))
            elif role == "actor":
                spawner.spawn(name, lambda n, ep, i=int(idx):
                              fabric.ActorWorker(
                                  task, _rollout, resolver, "batches",
                                  store_dir, registry, cfg, name=n,
                                  endpoint=ep, seed=100 + i))
            else:
                raise ValueError(name)

        self.sup = fabric.TrainSupervisor(
            self.registry, spawn_fn,
            expected={"replay": 1, "actor": actors, "learner": learners},
            policy=RestartPolicy(max_restarts=8, backoff_s=0.02),
            spawn_grace_s=10.0, total_steps=total_steps)

    def chief(self):
        for r in self.registry.lookup()["replicas"]:
            load = r["load"]
            if load.get("role") == "learner" and load.get("chief"):
                return load
        return None

    def kill(self, name: str) -> None:
        self._fabric.RegistryTarget(self.registry, name).kill()

    def versions(self):
        from repro.ckpt.checkpoint import ModelStore
        return ModelStore(self.store_dir).versions()

    def close(self) -> None:
        self.spawner.stop_all()


def _drive(fleet: _Fleet, events=(), timeout_s: float = 240.0):
    """Poll the supervisor to completion, firing each ``(trigger_step,
    fn)`` once when the chief's reported step first reaches the trigger.
    Returns (done, elapsed_s, final_chief_load, loss_curve)."""
    t0 = time.monotonic()
    fired = [False] * len(events)
    curve: list[tuple[float, int, float, int]] = []  # (t, step, loss, start)
    last = None
    while time.monotonic() - t0 < timeout_s:
        fleet.sup.poll()
        load = fleet.chief()
        if load is not None:
            last = load
            if load.get("loss") is not None and (
                    not curve or (curve[-1][1], curve[-1][3])
                    != (load["step"], load["start_step"])):
                curve.append((time.monotonic(), load["step"], load["loss"],
                              load["start_step"]))
            for i, (trig, fn) in enumerate(events):
                if not fired[i] and load["step"] >= trig:
                    fired[i] = True
                    fn()
        if fleet.sup.done:
            return True, time.monotonic() - t0, last, curve
        time.sleep(0.02)
    return False, time.monotonic() - t0, last, curve


def _late_loss(curve, total: int) -> float:
    tail = [loss for _, step, loss, _ in curve if step >= int(0.8 * total)]
    return float(np.mean(tail)) if tail else float("nan")


def run(emit) -> None:
    total = 40 if SMOKE else 80
    publish_every = 10
    # Kill the chief mid-publish-interval (not on a boundary) so the arm
    # shows a bounded-but-nonzero regression to the last published step.
    mid = total // 2 + 3

    # --- baseline: static 2-learner fleet ---------------------------------
    fleet = _Fleet(learners=2, actors=2, total_steps=total,
                   publish_every=publish_every)
    done, elapsed, load, curve = _drive(fleet)
    fleet.close()
    base_loss = _late_loss(curve, total)
    emit("train/baseline/step", 1e6 * elapsed / total if done else -1.0,
         f"steps_per_s={total/elapsed:.1f},learners=2,actors=2,"
         f"publish_every={publish_every},n={total}")
    emit("train/baseline/final_loss", base_loss if done else -1.0,
         f"late-window mean over steps >= {int(0.8*total)}")

    # --- kill one actor: stateless, zero lost steps -----------------------
    fleet = _Fleet(learners=2, actors=2, total_steps=total,
                   publish_every=publish_every)
    fleet_ref = fleet

    def _kill_actor():
        fleet_ref.kill("actor-0")
    done, elapsed, load, curve = _drive(fleet, [(total // 3, _kill_actor)])
    # The toy fleet can finish before the dead actor's TTL eviction lands;
    # keep polling briefly so the arm asserts the detect->respawn cycle
    # instead of passing vacuously.
    t_cap = time.monotonic() + 5.0
    while (done and not fleet.sup.stats()["restarts"].get("actor-0")
           and time.monotonic() < t_cap):
        fleet.sup.poll()
        time.sleep(0.02)
    stats = fleet.sup.stats()
    fleet.close()
    # Actors are stateless: the learner set must never blink. Lost steps
    # = the chief's restore regression (start_step stays 0 when it was
    # never restored); learner respawns are surfaced alongside.
    learner_restarts = sum(v for k, v in stats["restarts"].items()
                           if k.startswith("learner"))
    lost = load["start_step"] + learner_restarts if done else None
    emit("train/kill_actor/step", 1e6 * elapsed / total if done else -1.0,
         f"steps_per_s={total/elapsed:.1f}")
    emit("train/kill_actor/lost_steps",
         float(lost) if lost is not None else -1.0,
         f"actor_respawns={stats['restarts'].get('actor-0', 0)},"
         f"learner_respawns={learner_restarts},"
         f"chief_start={load['start_step'] if load else '?'} "
         "(CI gates == 0)")

    # --- kill the chief learner: bounded step loss ------------------------
    fleet = _Fleet(learners=2, actors=2, total_steps=total,
                   publish_every=publish_every)
    fleet_ref2 = fleet
    kill_info = {}

    def _kill_chief():
        kill_info["step"] = fleet_ref2.chief()["step"]
        kill_info["t"] = time.monotonic()
        fleet_ref2.kill("learner-0")
    done, elapsed, load, curve = _drive(fleet, [(mid, _kill_chief)])
    stats = fleet.sup.stats()
    fleet.close()
    if done and stats["restarts"].get("learner-0", 0) >= 1:
        step_loss = kill_info["step"] - load["start_step"]
        # Recovery: kill -> the respawned chief's first registry report
        # (identified by its restored, non-zero start_step).
        t_back = next((t for t, _, _, start in curve
                       if t > kill_info["t"] and start > 0), None)
        recovery_s = (t_back - kill_info["t"]) if t_back else -1e-6
    else:
        step_loss = None                      # kill missed: fail loudly
        recovery_s = -1e-6
    emit("train/kill_learner/step", 1e6 * elapsed / total if done else -1.0,
         f"steps_per_s={total/elapsed:.1f}")
    emit("train/kill_learner/step_loss",
         float(step_loss) if step_loss is not None else -1.0,
         f"killed_at={kill_info.get('step')},restored_start="
         f"{load['start_step'] if load else '?'},"
         f"publish_every={publish_every},"
         f"respawns={stats['restarts'].get('learner-0', 0)} "
         f"(CI gates <= {publish_every})")
    emit("train/kill_learner/recovery_s", recovery_s * 1e6,
         f"{recovery_s*1e3:.0f}ms kill -> restored chief reporting"
         if recovery_s >= 0 else "SENTINEL: chief respawn not observed")

    # --- elastic shrink 2 -> 1 -------------------------------------------
    fleet = _Fleet(learners=2, actors=2, total_steps=total,
                   publish_every=publish_every)
    fleet_ref3 = fleet
    done, elapsed, load, curve = _drive(
        fleet, [(total // 3, lambda: fleet_ref3.sup.scale("learner", 1))])
    fleet.close()
    shrink_loss = _late_loss(curve, total)
    emit("train/elastic_shrink/step",
         1e6 * elapsed / total if done else -1.0,
         f"steps_per_s={total/elapsed:.1f},2->1 at step {total//3}")
    emit("train/elastic_shrink/final_loss",
         shrink_loss if done else -1.0,
         f"delta_vs_baseline={shrink_loss-base_loss:+.4f}")

    # --- elastic grow 1 -> 2 ---------------------------------------------
    fleet = _Fleet(learners=1, actors=2, total_steps=total,
                   publish_every=publish_every)
    fleet_ref4 = fleet
    done, elapsed, load, curve = _drive(
        fleet, [(total // 3, lambda: fleet_ref4.sup.scale("learner", 2))])
    stats = fleet.sup.stats()
    fleet.close()
    grow_loss = _late_loss(curve, total)
    emit("train/elastic_grow/step", 1e6 * elapsed / total if done else -1.0,
         f"steps_per_s={total/elapsed:.1f},1->2 at step {total//3},"
         f"expected={stats['expected']}")
    emit("train/elastic_grow/final_loss", grow_loss if done else -1.0,
         f"delta_vs_baseline={grow_loss-base_loss:+.4f}")

    # --- compressed gradient wire (int8 + error feedback) -----------------
    from repro.train import grad_compression
    fleet = _Fleet(learners=2, actors=2, total_steps=total,
                   publish_every=publish_every, strategy="int8_ef")
    done, elapsed, load, curve = _drive(fleet)
    fleet.close()
    comp_loss = _late_loss(curve, total)
    # Wire shrink on this task's gradient tree (int8 q + fp32 scale/tensor).
    import jax
    probe = {"w1": np.zeros((4, 16), np.float32),
             "b1": np.zeros((16,), np.float32),
             "w2": np.zeros((16, 1), np.float32),
             "b2": np.zeros((1,), np.float32)}
    dense_b = grad_compression.grad_bytes(probe)
    payload, _ = grad_compression.compress_tree(probe, None, method="int8_ef")
    int8_b = sum(q.nbytes for q in jax.tree.leaves(payload["q"])) + \
        sum(s.nbytes for s in jax.tree.leaves(payload["scale"]))
    emit("train/compressed/step", 1e6 * elapsed / total if done else -1.0,
         f"steps_per_s={total/elapsed:.1f},strategy=int8_ef,"
         f"wire_bytes={int8_b}/{dense_b}")
    emit("train/compressed/final_loss", comp_loss if done else -1.0,
         f"delta_vs_baseline={comp_loss-base_loss:+.4f}")


if __name__ == "__main__":
    def _print(name, us, derived=""):
        print(f"{name},{us:.2f},{derived}")
    run(_print)
