"""Reverb-lite throughput: insert and sample rates, with/without the
samples-per-insert rate limiter."""

from __future__ import annotations

import time

import numpy as np

from repro.data.replay import ReplayServer, TableConfig


def run(emit):
    item = {"obs": np.zeros((16, 8), np.float32)}
    for spi in (None, 4.0):
        rs = ReplayServer([TableConfig("t", max_size=10_000,
                                       samples_per_insert=spi,
                                       min_size_to_sample=1)])
        n = 2000
        t0 = time.perf_counter()
        for i in range(n):
            rs.insert("t", item, timeout=1.0)
            if spi is not None and i % 2 == 0:
                rs.sample("t", int(spi) * 2, timeout=1.0)
        dt = (time.perf_counter() - t0) / n * 1e6
        emit(f"replay/insert/spi={spi}", dt, f"size={rs.size('t')}")

        # Stay within the SPI budget — sampling past it correctly blocks.
        m = 500 if spi is None else max(1, int(spi * n / 32) - n // 2)
        t0 = time.perf_counter()
        for _ in range(m):
            rs.sample("t", 32, timeout=1.0)
        dt = (time.perf_counter() - t0) / m * 1e6
        emit(f"replay/sample32/spi={spi}", dt, f"n={m}")
