"""Benchmark driver: one module per paper table/figure + system substrate.

    PYTHONPATH=src python -m benchmarks.run [--only param_server,...] \
        [--json OUT.json]

Prints ``name,us_per_call,derived`` CSV (one row per measurement), and
with ``--json`` also writes the rows to a JSON file (e.g. BENCH_rpc.json
for the rpc_overhead suite — CI records these). Writing merges by suite:
rows from suites *not* rerun are kept, so the BENCH_rpc and BENCH_serve
workflows can share or alternate files without clobbering each other.
  * param_server  — paper Figure 2 (QPS: single vs replicated vs cached)
  * rpc_overhead  — paper §1 zero-overhead claim (direct vs inproc vs gRPC)
  * replay        — reverb-lite insert/sample throughput + rate limiter
  * kernels       — Pallas kernels (interpret) vs oracles + analytic bytes
  * roofline      — per-cell roofline terms from the dry-run artifacts
  * serve         — continuous-batching vs lockstep serving A/B
  * train         — elastic training fabric chaos arms (kill/shrink/grow)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SUITES = ("rpc_overhead", "replay", "kernels", "param_server", "roofline",
          "serve", "train")

# Row-name prefix -> suite, for JSON files written before rows carried an
# explicit "suite" field.
_PREFIX_SUITE = {"rpc/": "rpc_overhead", "replay/": "replay",
                 "kernel/": "kernels", "ps/": "param_server",
                 "roofline/": "roofline", "serve/": "serve",
                 "train/": "train"}

_rows: list[dict] = []
_suite: list[str] = ["?"]


def _emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
    _rows.append({"name": name, "us_per_call": round(us_per_call, 2),
                  "derived": derived, "suite": _suite[0]})


def _row_suite(row: dict) -> str:
    suite = row.get("suite")
    if suite:
        return suite
    for prefix, inferred in _PREFIX_SUITE.items():
        if row.get("name", "").startswith(prefix):
            return inferred
    return "?"


def _write_json(path: str, ran: set[str]) -> None:
    """Merge this run's rows into ``path`` by suite: rerun suites replace
    their old rows wholesale; everything else is preserved."""
    kept, suites = [], set()
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            kept = [r for r in old.get("rows", [])
                    if _row_suite(r) not in ran]
            suites = {_row_suite(r) for r in kept} - {"?"}
        except (json.JSONDecodeError, OSError) as exc:
            print(f"ignoring unreadable {path}: {exc}", file=sys.stderr)
    rows = kept + _rows
    with open(path, "w") as f:
        json.dump({"suites": sorted(suites | ran), "rows": rows}, f,
                  indent=2)
        f.write("\n")
    print(f"wrote {len(_rows)} rows to {path} "
          f"({len(kept)} kept from other suites)", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge the emitted rows into a JSON file")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(SUITES)
    _rows.clear()

    def begin(suite: str):
        _suite[0] = suite
        return suite in only

    print("name,us_per_call,derived")
    if begin("rpc_overhead"):
        from benchmarks import rpc_overhead
        rpc_overhead.run(_emit)
    if begin("replay"):
        from benchmarks import replay_bench
        replay_bench.run(_emit)
    if begin("kernels"):
        from benchmarks import kernel_bench
        kernel_bench.run(_emit)
    if begin("param_server"):
        from benchmarks import param_server
        param_server.run(_emit)
    if begin("roofline"):
        from benchmarks import roofline_bench
        roofline_bench.run(_emit)
    if begin("serve"):
        from benchmarks import serve_bench
        serve_bench.run(_emit)
    if begin("train"):
        from benchmarks import train_bench
        train_bench.run(_emit)

    if args.json:
        _write_json(args.json, only & set(SUITES))


if __name__ == "__main__":
    main()
