"""Benchmark driver: one module per paper table/figure + system substrate.

    PYTHONPATH=src python -m benchmarks.run [--only param_server,...] \
        [--json OUT.json]

Prints ``name,us_per_call,derived`` CSV (one row per measurement), and
with ``--json`` also writes the rows to a JSON file (e.g. BENCH_rpc.json
for the rpc_overhead suite — CI records these):
  * param_server  — paper Figure 2 (QPS: single vs replicated vs cached)
  * rpc_overhead  — paper §1 zero-overhead claim (direct vs inproc vs gRPC)
  * replay        — reverb-lite insert/sample throughput + rate limiter
  * kernels       — Pallas kernels (interpret) vs oracles + analytic bytes
  * roofline      — per-cell roofline terms from the dry-run artifacts
"""

from __future__ import annotations

import argparse
import json
import sys

SUITES = ("rpc_overhead", "replay", "kernels", "param_server", "roofline")

_rows: list[dict] = []


def _emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
    _rows.append({"name": name, "us_per_call": round(us_per_call, 2),
                  "derived": derived})


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows to a JSON file")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(SUITES)
    _rows.clear()

    print("name,us_per_call,derived")
    if "rpc_overhead" in only:
        from benchmarks import rpc_overhead
        rpc_overhead.run(_emit)
    if "replay" in only:
        from benchmarks import replay_bench
        replay_bench.run(_emit)
    if "kernels" in only:
        from benchmarks import kernel_bench
        kernel_bench.run(_emit)
    if "param_server" in only:
        from benchmarks import param_server
        param_server.run(_emit)
    if "roofline" in only:
        from benchmarks import roofline_bench
        roofline_bench.run(_emit)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": sorted(only & set(SUITES)),
                       "rows": _rows}, f, indent=2)
            f.write("\n")
        print(f"wrote {len(_rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
