"""Zero-downtime rollout: registry drain marks, canary routing, the
RolloutController state machine (happy path, bad canary, mid-drain kill,
controller restart), and the real engine hot-swap."""

import threading
import time

import numpy as np
import pytest

from repro import core as lp
from repro.core.discovery import Heartbeater, Registry
from repro.serve.rollout import RolloutController
from repro.serve.router import Router, decorrelated_backoff


# -- fakes --------------------------------------------------------------------

class FakeReplica:
    """Version-aware engine replica: generate/load/health/load_version,
    with knobs for the failure paths (slow canary, failing swap, death)."""

    def __init__(self, name, version=0, num_slots=8, vocab=64):
        self.name = name
        self.version = version
        self.num_slots = num_slots
        self.calls = 0
        self.inflight = 0
        self.latency_s = 0.0
        self.fail_swap_to = None       # version id whose swap raises
        self.dead = False
        self.swaps = []
        self._lock = threading.Lock()

    def generate(self, prompt, max_new=4):
        if self.dead:
            raise ConnectionError(f"{self.name} is dead")
        with self._lock:
            self.calls += 1
            self.inflight += 1
        if self.latency_s:
            time.sleep(self.latency_s)
        with self._lock:
            self.inflight -= 1
        prompt = np.asarray(prompt)
        return np.concatenate([prompt, np.zeros(max_new, prompt.dtype)])

    def load(self):
        if self.dead:
            raise ConnectionError(f"{self.name} is dead")
        with self._lock:
            return {"num_slots": self.num_slots,
                    "free_slots": self.num_slots - self.inflight,
                    "queue_depth": 0, "version": self.version}

    def health(self):
        if self.dead:
            raise ConnectionError(f"{self.name} is dead")
        return {"status": "ok", "version": self.version}

    def load_version(self, version):
        if self.dead:
            raise ConnectionError(f"{self.name} is dead")
        if self.fail_swap_to is not None and int(version) == self.fail_swap_to:
            raise ValueError("shape mismatch: bad published version")
        self.version = int(version)
        self.swaps.append(int(version))
        return {"version": self.version}

    def kill(self):
        self.dead = True


class _Fleet:
    """Registry + heartbeating fake replicas + a router over them."""

    def __init__(self, n=2, ttl_s=5.0, heartbeat_s=0.02, **rep_kw):
        self.registry = Registry(ttl_s=ttl_s)
        self.replicas = [FakeReplica(f"rep-{i}", **rep_kw) for i in range(n)]
        self.by_endpoint = {}
        self.beaters = []
        for rep in self.replicas:
            ep = f"fake://{rep.name}"
            self.by_endpoint[ep] = rep
            self.beaters.append(Heartbeater(
                self.registry, rep.name, ep, load_fn=rep.load,
                period_s=heartbeat_s).start())
        self.router = Router(self.registry, refresh_s=0.01,
                             startup_wait_s=2.0, coalesce=False,
                             client_factory=self.client_for)

    def client_for(self, endpoint):
        rep = self.by_endpoint[endpoint]

        class _Client:
            class futures:
                @staticmethod
                def generate(prompt, **kw):
                    from concurrent import futures as cf
                    fut = cf.Future()
                    try:
                        fut.set_result(rep.generate(prompt, **kw))
                    except BaseException as exc:  # noqa: BLE001
                        fut.set_exception(exc)
                    return fut

            generate = staticmethod(rep.generate)
            load = staticmethod(rep.load)
            health = staticmethod(rep.health)
            load_version = staticmethod(rep.load_version)

        return _Client()

    def controller(self, **kw):
        kw.setdefault("client_factory", self.client_for)
        kw.setdefault("drain_timeout_s", 5.0)
        kw.setdefault("poll_s", 0.005)
        kw.setdefault("canary_timeout_s", 2.0)
        return RolloutController(self.registry, [self.router], **kw)

    def wait_routable(self, n):
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            if self.router.health()["replicas"] >= n:
                return
            time.sleep(0.01)
        raise AssertionError("router never saw the fleet")

    def close(self):
        self.router.close()
        for b in self.beaters:
            b.stop()


@pytest.fixture
def fleet():
    f = _Fleet()
    f.wait_routable(2)
    yield f
    f.close()


# -- registry drain marks -----------------------------------------------------

def test_set_draining_marks_and_generation():
    reg = Registry(ttl_s=5.0)
    reg.register("a", "fake://a", {"version": 0})
    g0 = reg.lookup()["generation"]
    assert reg.set_draining("a", True)
    view = reg.lookup()
    assert view["replicas"][0]["draining"] is True
    assert view["generation"] > g0
    assert reg.version_table()["a"]["draining"] is True
    # idempotent set does not churn the generation
    g1 = reg.lookup()["generation"]
    reg.set_draining("a", True)
    assert reg.lookup()["generation"] == g1
    assert not reg.set_draining("ghost", True)
    # re-registration clears the mark (recovered replica is dispatchable)
    reg.register("a", "fake://a", {"version": 0})
    assert reg.lookup()["replicas"][0]["draining"] is False


def test_router_skips_draining_replica(fleet):
    fleet.registry.set_draining("rep-0", True)
    deadline = time.monotonic() + 2.0
    while time.monotonic() < deadline:
        if fleet.router.health()["dispatchable"] == 1:
            break
        time.sleep(0.01)
    assert fleet.router.health()["dispatchable"] == 1
    for _ in range(6):
        fleet.router.submit(np.arange(4, dtype=np.int32), max_new=2)
    assert fleet.replicas[0].calls == 0
    assert fleet.replicas[1].calls == 6


def test_version_table_tracks_heartbeat_versions(fleet):
    fleet.replicas[1].version = 7
    deadline = time.monotonic() + 2.0
    while time.monotonic() < deadline:
        table = fleet.registry.version_table()
        if table.get("rep-1", {}).get("version") == 7:
            break
        time.sleep(0.01)
    table = fleet.registry.version_table()
    assert table["rep-0"]["version"] == 0
    assert table["rep-1"]["version"] == 7


# -- canary routing -----------------------------------------------------------

def test_canary_fraction_is_metered(fleet):
    fleet.replicas[1].version = 1
    deadline = time.monotonic() + 2.0
    while time.monotonic() < deadline:
        stats = fleet.router.stats()["replicas"]
        if stats.get("rep-1", {}).get("version") == "1":
            break
        time.sleep(0.01)
    fleet.router.set_canary(1, 0.25)
    for _ in range(16):
        fleet.router.submit(np.arange(4, dtype=np.int32), max_new=2)
    # Deterministic accumulator: exactly 1/4 of requests hit the canary,
    # and baseline traffic is steered *away* from it.
    assert fleet.replicas[1].calls == 4
    assert fleet.replicas[0].calls == 12
    per_version = fleet.router.stats()["per_version"]
    assert per_version["1"]["completed"] == 4
    assert per_version["0"]["completed"] == 12
    assert per_version["1"]["us_per_token"] > 0
    fleet.router.set_canary(None)
    fleet.router.submit(np.arange(4, dtype=np.int32), max_new=2)
    assert fleet.replicas[0].calls + fleet.replicas[1].calls == 17


def test_decorrelated_backoff_spreads_and_caps():
    rng = np.random.default_rng(0)
    sleeps = set()
    prev = 0.0
    for _ in range(32):
        prev = decorrelated_backoff(prev, rng, base_s=0.005, cap_s=0.1)
        assert 0.005 <= prev <= 0.1
        sleeps.add(round(prev, 6))
    assert len(sleeps) > 16          # jittered, not a fixed schedule


# -- the controller -----------------------------------------------------------

def _traffic(fleet, stop, counts):
    """Background closed-loop client; Overloaded retried with jitter."""
    rng = np.random.default_rng(1)
    backoff = 0.0
    while not stop.is_set():
        try:
            out = fleet.router.submit(np.arange(4, dtype=np.int32),
                                      max_new=2)
            assert len(out) == 6
            counts["ok"] += 1
            backoff = 0.0
        except Exception as exc:  # noqa: BLE001
            from repro.serve.router import is_overloaded
            if is_overloaded(exc):
                backoff = decorrelated_backoff(backoff, rng)
                time.sleep(backoff)
            else:
                counts["lost"] += 1


def test_rollout_happy_path_zero_lost(fleet):
    stop, counts = threading.Event(), {"ok": 0, "lost": 0}
    dips = []
    sampler_stop = threading.Event()

    def sample():
        while not sampler_stop.is_set():
            dips.append(fleet.router.health()["dispatchable"])
            time.sleep(0.002)

    threads = [threading.Thread(target=_traffic,
                                args=(fleet, stop, counts), daemon=True)
               for _ in range(3)]
    threads.append(threading.Thread(target=sample, daemon=True))
    for t in threads:
        t.start()
    try:
        result = fleet.controller(canary_fraction=0.5,
                                  canary_requests=4).rollout(1)
    finally:
        sampler_stop.set()
        stop.set()
        for t in threads:
            t.join(timeout=5)
    assert result["status"] == "promoted"
    assert result["canary"] is not None and result["canary"]["ok"]
    assert all(r.version == 1 for r in fleet.replicas)
    table = fleet.registry.version_table()
    assert all(not info["draining"] for info in table.values())
    assert counts["lost"] == 0
    assert counts["ok"] > 0
    # One replica drains at a time: the fleet never dropped below N-1.
    assert min(dips) >= 1


def test_rollout_bad_swap_rolls_back_fleet_wide(fleet):
    # First replica (the canary) swaps fine; the second one's swap blows
    # up (e.g. a version published for another architecture). The
    # controller must re-pin the already-updated canary back to v0.
    fleet.replicas[1].fail_swap_to = 1
    result = fleet.controller(canary_requests=0).rollout(1)
    assert result["status"] == "rolled_back"
    assert "rep-1" in result["reason"]
    assert all(r.version == 0 for r in fleet.replicas)
    assert all(not info["draining"]
               for info in fleet.registry.version_table().values())


def test_rollout_canary_regression_rolls_back(fleet):
    # The new version is healthy but slow: the canary comparison, not the
    # health probe, must catch it and restore v0 everywhere.
    stop, counts = threading.Event(), {"ok": 0, "lost": 0}
    orig = fleet.replicas[0].load_version

    def slow_swap(version):
        out = orig(version)
        fleet.replicas[0].latency_s = 0.03 if int(version) == 1 else 0.0
        return out

    fleet.replicas[0].load_version = slow_swap
    threads = [threading.Thread(target=_traffic,
                                args=(fleet, stop, counts), daemon=True)
               for _ in range(3)]
    for t in threads:
        t.start()
    try:
        result = fleet.controller(canary_fraction=0.5, canary_requests=6,
                                  canary_timeout_s=10.0,
                                  regression_ratio=2.0).rollout(1)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5)
    assert result["status"] == "rolled_back"
    assert result["reason"].startswith("canary")
    assert not result["canary"]["ok"]
    assert all(r.version == 0 for r in fleet.replicas)
    assert counts["lost"] == 0


def test_rollout_survives_mid_drain_kill(fleet):
    # Chaos: the first replica dies while draining. The controller must
    # detect it, skip it, and finish rolling the survivor — zero lost.
    fleet.replicas[0].inflight = 1       # pins the drain wait open
    injector = lp.FaultInjector(
        [lp.FaultEvent(kind="kill", target=0,
                       when=lambda: fleet.registry.version_table()
                       .get("rep-0", {}).get("draining", False))],
        [fleet.replicas[0]])
    done = threading.Event()

    def chaos():
        while not done.is_set() and injector.poll():
            time.sleep(0.002)

    t = threading.Thread(target=chaos, daemon=True)
    t.start()
    try:
        result = fleet.controller(canary_requests=0).rollout(1)
    finally:
        done.set()
        t.join(timeout=5)
    assert injector.fired and injector.fired[0]["kind"] == "kill"
    assert result["status"] == "promoted"
    assert result["replicas"]["rep-0"] == "dead"
    assert result["replicas"]["rep-1"] == "swapped"
    assert fleet.replicas[1].version == 1


def test_rollout_resumes_from_registry_state(fleet):
    # Controller "dies" after rolling the first replica; a fresh
    # controller re-derives progress from the registry's version table
    # and only touches the remaining replica.
    fleet.replicas[0].load_version(1)
    deadline = time.monotonic() + 2.0
    while time.monotonic() < deadline:
        if fleet.registry.version_table()["rep-0"]["version"] == 1:
            break
        time.sleep(0.01)
    result = fleet.controller(canary_requests=0).rollout(1)
    assert result["status"] == "promoted"
    assert list(result["replicas"]) == ["rep-1"]     # rep-0 untouched
    assert fleet.replicas[0].swaps == [1]            # exactly once, by us
    assert fleet.replicas[1].swaps == [1]


# -- the real engine ----------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_cfg():
    from repro import configs
    return configs.get_reduced("qwen2-1.5b")


def test_engine_swap_params_applies_between_windows(tiny_cfg):
    import jax
    from repro.models import transformer
    from repro.serve.engine import ServeEngine
    p0 = transformer.init_params(tiny_cfg, jax.random.key(0))
    p1 = transformer.init_params(tiny_cfg, jax.random.key(1))
    prompt = np.arange(1, 7, dtype=np.int32)

    eng = ServeEngine(tiny_cfg, p0, num_slots=2, context_len=32, max_new=4)
    fut = eng.submit(prompt)
    while not fut.done():
        eng.step()
    out_v0 = np.asarray(fut.result())
    # Externally-stepped engine: the swap lands on the next step() call.
    eng.swap_params(p1, block=False)
    fut = eng.submit(prompt)
    while not fut.done():
        eng.step()
    out_v1 = np.asarray(fut.result())
    assert eng.stats()["param_swaps"] == 1
    eng.stop()

    solo = ServeEngine(tiny_cfg, p1, num_slots=2, context_len=32, max_new=4)
    fut = solo.submit(prompt)
    while not fut.done():
        solo.step()
    expected = np.asarray(fut.result())
    solo.stop()
    np.testing.assert_array_equal(out_v1, expected)
    assert not np.array_equal(out_v0, out_v1)   # the weights really moved


def test_engine_server_load_version_roundtrip(tiny_cfg, tmp_path):
    import jax
    from repro.ckpt.checkpoint import ModelStore, config_hash
    from repro.launch.serve import EngineServer
    from repro.models import transformer

    store = ModelStore(str(tmp_path / "store"))
    for v in (0, 1):
        store.publish_version(
            v, transformer.init_params(tiny_cfg, jax.random.key(v)),
            metadata={"step": v, "config_hash": config_hash(tiny_cfg)})
    registry = Registry(ttl_s=5.0)
    server = EngineServer(tiny_cfg, max_new=4, num_slots=2, context_len=32,
                          registry=registry, heartbeat_s=0.05,
                          name="rep-0", endpoint="fake://rep-0",
                          store_dir=str(tmp_path / "store"), version=0)
    try:
        assert server.load()["version"] == 0
        out0 = np.asarray(server.generate(np.arange(1, 7, dtype=np.int32)))
        server.load_version(1)
        assert server.health()["version"] == 1
        # beat_now() pushed the new version without waiting a period
        assert registry.version_table()["rep-0"]["version"] == 1
        out1 = np.asarray(server.generate(np.arange(1, 7, dtype=np.int32)))
        assert not np.array_equal(out0, out1)
        # a version that was never published fails before any swap
        with pytest.raises(FileNotFoundError):
            server.load_version(9)
        assert server.load()["version"] == 1
    finally:
        server.kill()
