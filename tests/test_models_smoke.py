"""Per-architecture smoke tests (brief requirement): instantiate a REDUCED
config of each assigned family and run one forward/train step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via
the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.models.config import shape_applicability, ALL_SHAPES
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig, make_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    batch = {}
    if cfg.family == "audio":
        batch["embeddings"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.bfloat16)
        batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["mask"] = jnp.ones((B, S), jnp.float32)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["tokens"] = toks
        batch["labels"] = toks
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(
                key, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.key(0)
    params = transformer.init_params(cfg, key)
    batch = _batch(cfg, key)
    hidden, aux = transformer.forward(
        cfg, params, tokens=batch.get("tokens"),
        embeddings=batch.get("embeddings"),
        memory=batch.get("image_embeds"))
    assert hidden.shape == (B, S, cfg.d_model)
    logits = transformer.logits_from_hidden(cfg, params, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_one_train_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.key(1)
    step = jax.jit(make_train_step(cfg, TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        num_microbatches=2)))
    params, opt = make_train_state(cfg, key)
    batch = jax.tree.map(jnp.asarray, _batch(cfg, key))
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_decode_step_or_skip(arch):
    cfg = configs.get_reduced(arch)
    if not cfg.decode_supported:
        pytest.skip("encoder-only: no decode step")
    key = jax.random.key(2)
    params = transformer.init_params(cfg, key)
    state = transformer.init_decode_state(cfg, B, 64)
    toks = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, state2 = transformer.decode_step(cfg, params, state, toks,
                                             jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_prefill_matches_forward(arch):
    cfg = configs.get_reduced(arch)
    if not cfg.decode_supported:
        pytest.skip("encoder-only: prefill == forward by construction")
    key = jax.random.key(3)
    params = transformer.init_params(cfg, key)
    batch = _batch(cfg, key)
    hidden, _ = transformer.forward(cfg, params, tokens=batch.get("tokens"),
                                    memory=batch.get("image_embeds"))
    logits_fwd = transformer.logits_from_hidden(cfg, params, hidden)
    logits_pf, state = transformer.prefill(
        cfg, params, tokens=batch.get("tokens"),
        memory=batch.get("image_embeds"), context_len=64)
    np.testing.assert_allclose(np.asarray(logits_fwd, np.float32),
                               np.asarray(logits_pf, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert state


def test_param_counts_match_published():
    known = {
        "hubert-xlarge": 0.96e9, "qwen2-1.5b": 1.54e9,
        "command-r-plus-104b": 104e9, "starcoder2-3b": 3.0e9,
        "qwen3-8b": 8.2e9, "llama-3.2-vision-11b": 9.8e9,
        "mixtral-8x22b": 141e9, "mixtral-8x7b": 46.7e9,
        "recurrentgemma-2b": 2.7e9, "falcon-mamba-7b": 7.3e9,
    }
    for arch, expect in known.items():
        n = configs.get(arch).param_count()
        assert abs(n - expect) / expect < 0.08, (arch, n, expect)


def test_mixtral_active_params():
    cfg = configs.get("mixtral-8x22b")
    assert abs(cfg.active_param_count() - 39e9) / 39e9 < 0.05


def test_shape_applicability_matrix():
    rows = {(a, s.name): shape_applicability(configs.get(a), s)
            for a in configs.ARCH_NAMES for s in ALL_SHAPES}
    # hubert: no decode shapes
    assert rows[("hubert-xlarge", "decode_32k")] is not None
    assert rows[("hubert-xlarge", "long_500k")] is not None
    # full-attention archs skip long_500k
    for a in ("qwen2-1.5b", "qwen3-8b", "command-r-plus-104b",
              "llama-3.2-vision-11b"):
        assert rows[(a, "long_500k")] is not None
    # sub-quadratic archs run long_500k
    for a in ("falcon-mamba-7b", "recurrentgemma-2b", "mixtral-8x7b",
              "mixtral-8x22b", "starcoder2-3b"):
        assert rows[(a, "long_500k")] is None
    # 34 runnable cells, 6 structurally inapplicable
    runnable = sum(1 for v in rows.values() if v is None)
    assert runnable == 34
