"""fault.py primitives: restart policies, hedged_map paths, the launcher
restart loop, and the FaultInjector's trigger machinery."""

import threading
import time
from concurrent import futures as cf

import pytest

from repro import core as lp
from repro.core.fault import (ALWAYS_RESTART, NO_RESTART, FaultEvent,
                              FaultInjector, RestartPolicy, hedged_map)


# -- RestartPolicy edges ------------------------------------------------------

def test_backoff_grows_exponentially_and_caps():
    p = RestartPolicy(backoff_s=0.1, backoff_multiplier=2.0, max_backoff_s=0.5)
    assert p.backoff_for(0) == pytest.approx(0.1)
    assert p.backoff_for(1) == pytest.approx(0.2)
    assert p.backoff_for(2) == pytest.approx(0.4)
    assert p.backoff_for(3) == pytest.approx(0.5)      # capped
    assert p.backoff_for(50) == pytest.approx(0.5)     # no overflow blowup


def test_allows_edges():
    assert not NO_RESTART.allows(0)                    # fail fast
    assert ALWAYS_RESTART.allows(10**6)                # restart forever
    p = RestartPolicy(max_restarts=2)
    assert p.allows(0) and p.allows(1)
    assert not p.allows(2)


# -- hedged_map ---------------------------------------------------------------

def _resolved(value):
    fut = cf.Future()
    fut.set_result(value)
    return fut


def test_hedged_map_all_complete():
    out = hedged_map([lambda v=v: _resolved(v) for v in range(4)])
    assert out == [0, 1, 2, 3]


def test_hedged_map_hedge_wins():
    # First issue of fn[1] never resolves; the hedge re-issue resolves
    # immediately — the hedged request must win and unblock the map.
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        return cf.Future() if calls["n"] == 1 else _resolved("hedged")

    out = hedged_map([lambda: _resolved("fast"), flaky],
                     hedge_after_s=0.05, timeout_s=5.0)
    assert out == ["fast", "hedged"]
    assert calls["n"] == 2


def test_hedged_map_quorum_cancels_stragglers():
    straggler = cf.Future()     # never resolves; quorum cancels it
    out = hedged_map([lambda: _resolved("a"), lambda: _resolved("b"),
                      lambda: straggler], quorum=2)
    assert out == ["a", "b", None]
    assert straggler.cancelled()


def test_hedged_map_timeout_raises():
    with pytest.raises(TimeoutError):
        hedged_map([lambda: cf.Future()], timeout_s=0.1)


def test_hedged_map_first_error_propagates():
    boom = cf.Future()
    boom.set_exception(RuntimeError("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        hedged_map([lambda: _resolved(1), lambda: boom])


# -- launcher restart-with-backoff -------------------------------------------

class _FlakyNode:
    """Crashes on its first ``fail_times`` constructions, then succeeds.
    Module-level state keyed by tag: the launcher re-constructs the
    object on every restart, so instance state would reset."""
    attempts: dict = {}

    def __init__(self, tag: str, fail_times: int):
        n = _FlakyNode.attempts.get(tag, 0)
        _FlakyNode.attempts[tag] = n + 1
        if n < fail_times:
            raise RuntimeError(f"flaky crash #{n}")

    def run(self):
        lp.stop_program()


def test_thread_launcher_restarts_with_backoff():
    _FlakyNode.attempts.clear()
    p = lp.Program("flaky")
    with p.group("w"):
        p.add_node(lp.PyNode(_FlakyNode, "a", 2))
    launcher = lp.ThreadLauncher(
        per_group_restart={"w": RestartPolicy(max_restarts=3,
                                             backoff_s=0.01)})
    t0 = time.monotonic()
    launcher.launch(p)
    assert launcher.wait(timeout=10)
    assert _FlakyNode.attempts["a"] == 3          # 2 crashes + 1 success
    failures = launcher.failures
    assert len(failures) == 2
    assert all(not f.fatal for f in failures)
    assert time.monotonic() - t0 >= 0.01 + 0.02   # backoffs were honored


def test_thread_launcher_fatal_after_restart_budget():
    _FlakyNode.attempts.clear()
    p = lp.Program("doomed")
    with p.group("w"):
        p.add_node(lp.PyNode(_FlakyNode, "b", 99))
    launcher = lp.ThreadLauncher(
        per_group_restart={"w": RestartPolicy(max_restarts=1,
                                             backoff_s=0.01)})
    launcher.launch(p)
    assert launcher.wait(timeout=10)
    assert any(f.fatal for f in launcher.failures)
    assert _FlakyNode.attempts["b"] == 2          # initial + 1 restart


# -- FaultInjector ------------------------------------------------------------

class _Target:
    def __init__(self):
        self.calls = []
        self.dead = False

    def kill(self):
        if self.dead:
            raise ConnectionError("already dead")
        self.dead = True
        self.calls.append(("kill",))

    def stall(self, seconds):
        self.calls.append(("stall", seconds))

    def drop(self, seconds):
        self.calls.append(("drop", seconds))


class _Progress:
    def __init__(self):
        self.completed = 0

    def stats(self):
        return {"completed": self.completed}


def test_fault_injector_count_trigger():
    target, progress = _Target(), _Progress()
    inj = FaultInjector([FaultEvent(kind="kill", after_served=5)],
                        [target], progress=[progress])
    assert inj.poll() == 1                # 0 served: not due
    assert target.calls == []
    progress.completed = 5
    assert inj.poll() == 0
    assert target.calls == [("kill",)]
    assert inj.fired[0]["error"] is None


def test_fault_injector_time_and_predicate_triggers():
    target = _Target()
    gate = threading.Event()
    inj = FaultInjector(
        [FaultEvent(kind="stall", after_s=0.02, duration_s=1.5),
         FaultEvent(kind="drop", when=gate.is_set, duration_s=0.5)],
        [target])
    inj.poll()
    assert target.calls == []             # neither due yet
    time.sleep(0.03)
    assert inj.poll() == 1                # stall fired, drop waiting
    assert target.calls == [("stall", 1.5)]
    gate.set()
    assert inj.poll() == 0
    assert target.calls == [("stall", 1.5), ("drop", 0.5)]


def test_fault_injector_records_failed_fire():
    target = _Target()
    target.dead = True                    # kill() raises
    inj = FaultInjector([FaultEvent(kind="kill")], [target])
    assert inj.poll() == 0                # fired (best-effort), not pending
    assert inj.fired[0]["error"] is not None
    assert target.calls == []
