"""Transport stack: framed zero-copy wire format, unified client over both
transports, batched RPC semantics, channel pooling, lifecycle hygiene."""

import threading
from collections import namedtuple

import numpy as np
import pytest

from repro.core import courier, handles
from repro.core.courier import serialization as ser
from repro.core.courier.client import CourierClient
from repro.core.courier.server import CourierServer

Point = namedtuple("Point", "x y")


class Service:
    def add(self, a, b=0):
        return a + b

    def echo(self, x):
        return x

    def scale_point(self, p, k):
        return Point(p.x * k, p.y * k)

    def boom(self):
        raise ValueError("intentional")

    def whoami_thread(self):
        return threading.current_thread().name


@pytest.fixture(params=["grpc", "inproc", "shm"])
def client(request):
    svc = Service()
    if request.param == "grpc":
        srv = CourierServer(svc)
        srv.start()
        cli = courier.client_for(srv.endpoint)
        yield cli
        cli.close()
        srv.stop()
    elif request.param == "shm":
        import os
        import time
        name = f"tt{os.getpid():x}{time.monotonic_ns() & 0xffffff:x}"
        srv = CourierServer(svc, shm_name=name)
        srv.start()
        cli = courier.client_for(f"shm://{name}+{srv.endpoint}")
        assert isinstance(cli.transport, courier.ShmTransport)
        yield cli
        cli.close()
        srv.stop()
    else:
        courier.inprocess.register("transport_svc", svc)
        yield courier.client_for("inproc://transport_svc")
        courier.inprocess.unregister("transport_svc")


# ---- wire format -------------------------------------------------------------

def test_large_array_roundtrip_both_transports(client):
    arr = np.arange(1 << 20, dtype=np.float32)  # 4 MiB
    out = client.echo(arr)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_framed_encode_keeps_arrays_out_of_band():
    arr = np.zeros(1 << 20, np.float32)  # 4 MiB payload
    data = ser.dumps({"x": arr, "tag": "t"})
    assert ser.is_framed(data)
    # The pickle stream (frame 0) must stay tiny: the array travels as an
    # out-of-band frame, not embedded bytes.
    mv = memoryview(data)
    (nframes,) = ser._NFRAMES.unpack_from(mv, 2)
    assert nframes >= 2
    (stream_len,) = ser._FRAMELEN.unpack_from(mv, 2 + ser._NFRAMES.size)
    assert stream_len < 4096
    out = ser.loads(data)
    np.testing.assert_array_equal(out["x"], arr)


def test_decoded_arrays_are_zero_copy_views():
    data = ser.dumps(np.arange(1024, dtype=np.int32))
    out = ser.loads(data)
    assert out.base is not None        # aliases the received message...
    assert not out.flags.writeable     # ...so it is read-only by contract
    np.testing.assert_array_equal(np.copy(out), np.arange(1024))


def test_jax_leaves_transport_without_deep_copy_pass(client):
    import jax.numpy as jnp
    out = client.echo({"p": jnp.ones((128,)), "n": 3})
    np.testing.assert_array_equal(np.asarray(out["p"]), np.ones(128))
    assert out["n"] == 3


def test_namedtuple_survives_serialization():
    out = ser.loads(ser.dumps(Point(1, np.ones(4))))
    assert type(out).__name__ == "Point"
    assert out.x == 1
    np.testing.assert_array_equal(out.y, np.ones(4))


def test_namedtuple_survives_rpc(client):
    out = client.scale_point(Point(2, 3), 10)
    assert isinstance(out, tuple) and type(out).__name__ == "Point"
    assert out == (20, 30)


def test_legacy_wire_format_interops_with_framed_server():
    srv = CourierServer(Service())
    srv.start()
    try:
        with CourierClient(srv.endpoint, wire_format="legacy") as legacy:
            assert legacy.add(2, b=3) == 5
            arr = np.arange(4096, dtype=np.float32)
            np.testing.assert_array_equal(legacy.echo(arr), arr)
            with pytest.raises(courier.RemoteError, match="intentional"):
                legacy.boom()
    finally:
        srv.stop()


# ---- futures & errors --------------------------------------------------------

def test_remote_error_through_futures_grpc():
    srv = CourierServer(Service())
    srv.start()
    try:
        with courier.client_for(srv.endpoint) as cli:
            fut = cli.futures.boom()
            with pytest.raises(courier.RemoteError, match="intentional"):
                fut.result(timeout=10)
    finally:
        srv.stop()


def test_inproc_futures_raise_original_exception():
    courier.inprocess.register("err_svc", Service())
    try:
        cli = courier.client_for("inproc://err_svc")
        with pytest.raises(ValueError, match="intentional"):
            cli.futures.boom().result(timeout=10)
    finally:
        courier.inprocess.unregister("err_svc")


def test_inproc_refuses_run_and_private_like_grpc():
    class WithRun(Service):
        def run(self):
            raise AssertionError("run must not be callable remotely")

    courier.inprocess.register("run_svc", WithRun())
    try:
        cli = courier.client_for("inproc://run_svc")
        with pytest.raises(courier.RemoteError):
            cli.run()
        with pytest.raises(AttributeError):
            cli._private()
    finally:
        courier.inprocess.unregister("run_svc")


# ---- batched RPC -------------------------------------------------------------

def test_batch_call_preserves_order(client):
    calls = [("add", (i,), {"b": 100}) for i in range(32)]
    assert client.batch_call(calls) == [100 + i for i in range(32)]


def test_batch_call_error_isolation(client):
    calls = [("add", (1,), {}), ("boom", (), {}), ("add", (2,), {})]
    out = client.batch_call(calls, return_exceptions=True)
    assert out[0] == 1 and out[2] == 2
    assert isinstance(out[1], courier.RemoteError)
    with pytest.raises(courier.RemoteError):
        client.batch_call(calls)


def test_batch_call_future(client):
    fut = client.futures.batch_call(
        [("add", (i,), {}) for i in range(4)] + [("boom", (), {})])
    out = fut.result(timeout=10)
    assert out[:4] == [0, 1, 2, 3]
    assert isinstance(out[4], Exception)


def test_batch_call_ships_shared_buffers_once():
    arrs = [np.full(1024, i, np.float32) for i in range(4)]
    data = ser.encode_batch_call([("echo", (a,), {}) for a in arrs])
    calls = ser.decode_batch_call(data)
    for i, (method, args, _) in enumerate(calls):
        assert method == "echo"
        np.testing.assert_array_equal(args[0], arrs[i])


# ---- channel pooling & lifecycle --------------------------------------------

def test_channel_pool_shared_and_released():
    srv = CourierServer(Service())
    srv.start()
    target = srv.endpoint[len("grpc://"):]
    try:
        a = courier.client_for(srv.endpoint)
        b = courier.client_for(srv.endpoint)
        assert a.add(1) == 1 and b.add(2) == 2  # both force channel acquire
        assert courier.channel_pool_stats().get(target) == 2
        a.close()
        assert courier.channel_pool_stats().get(target) == 1
        b.close()
        b.close()  # double-close is a no-op
        assert target not in courier.channel_pool_stats()
    finally:
        srv.stop()


def test_client_context_manager_and_server_double_stop():
    srv = CourierServer(Service())
    srv.start()
    with courier.client_for(srv.endpoint) as cli:
        assert cli.add(3, b=4) == 7
    srv.stop()
    srv.stop()  # idempotent
    never_started = CourierServer(Service())
    never_started.stop()  # stop before start is safe too


# ---- telemetry: transport I/O counters ---------------------------------------

def test_transport_stats_counters(client):
    """Every transport exposes cumulative wire counters through
    ``stats()`` — the payload a node's ``telemetry()`` RPC surfaces."""
    tr = client.transport
    base = tr.stats()
    for key in ("calls", "batch_calls", "batched_calls_in_frames",
                "errors", "bytes_out", "bytes_in", "serialize_us",
                "pool_grows"):
        assert key in base
    client.add(1, b=2)
    arr = np.arange(4096, dtype=np.float32)
    np.testing.assert_array_equal(client.echo(arr), arr)
    client.batch_call([("add", (i,), {}) for i in range(3)])
    s = tr.stats()
    if isinstance(tr, courier.InProcTransport):
        # Inproc batch entries route through call(): 2 singles + 3 batched.
        assert s["calls"] - base["calls"] == 5
    else:
        assert s["calls"] - base["calls"] == 2        # add + echo
    assert s["batch_calls"] - base["batch_calls"] == 1
    assert (s["batched_calls_in_frames"]
            - base["batched_calls_in_frames"]) == 3
    if isinstance(tr, courier.InProcTransport):
        # No wire: byte counters stay zero, but app errors still count.
        assert s["bytes_out"] == 0 and s["bytes_in"] == 0
        with pytest.raises(ValueError, match="intentional"):
            client.boom()
        assert tr.stats()["errors"] - base["errors"] >= 1
    else:
        # The 16 KiB echo array dominates both directions.
        assert s["bytes_out"] - base["bytes_out"] > 4096 * 4
        assert s["bytes_in"] - base["bytes_in"] > 4096 * 4
        assert s["serialize_us"] > base["serialize_us"]


def test_shm_transport_stats_count_pool_grows():
    """A message larger than the ring's largest preallocated slot forces
    a slot-pool grow; the transport's stats surface the event."""
    import os
    import time
    name = f"tg{os.getpid():x}{time.monotonic_ns() & 0xffffff:x}"
    srv = CourierServer(Service(), shm_name=name)
    srv.start()
    cli = courier.client_for(f"shm://{name}+{srv.endpoint}")
    try:
        assert isinstance(cli.transport, courier.ShmTransport)
        big = np.zeros(64 << 20, np.uint8)       # 64 MiB: beyond any slot
        out = cli.echo(big)
        assert out.nbytes == big.nbytes
        assert cli.transport.stats()["pool_grows"] >= 1
    finally:
        cli.close()
        srv.stop()


def test_map_handles_preserves_namedtuple():
    out = handles.map_handles(Point([1, 2], {"k": (3,)}), lambda h: h)
    assert type(out).__name__ == "Point"
    assert out.x == [1, 2] and out.y == {"k": (3,)}
