"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Brief requirement: "For each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracle."
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, KV, dh, causal, window
    (2, 128, 128, 4, 2, 64, True, None),
    (1, 256, 256, 4, 4, 64, True, 64),
    (2, 128, 256, 4, 1, 64, True, None),      # Sq < Sk (right-aligned)
    (1, 128, 128, 2, 2, 32, False, None),     # encoder / bidirectional
    (1, 512, 512, 8, 2, 128, True, 128),      # GQA + window
    (3, 64, 64, 2, 1, 128, True, None),       # MQA
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Sk, H, KV, dh, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_invariance():
    B, S, H, KV, dh = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
    outs = [np.asarray(ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                                           interpret=True))
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 4, 2, 64, 512),
    (1, 8, 1, 128, 1024),
    (3, 4, 4, 32, 512),
    (1, 16, 8, 128, 2048),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(case, dtype):
    B, H, KV, dh, L = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, L, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, L, KV, dh), dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (B, L)).at[:, 0].set(True)
    out = ops.decode_attention(q, k, v, valid, block_l=256, interpret=True)
    expect = ref.decode_attention(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_decode_attention_dispatch_paths_agree():
    """Both dispatcher leaves — the Pallas body (interpret) and the jit'd
    oracle — must agree on ragged masks INCLUDING an all-invalid row,
    where the shared contract is zeros (the kernel's online-softmax
    accumulator never runs for such a row)."""
    B, H, KV, dh, L = 3, 4, 2, 64, 256
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KV, dh), jnp.float32)
    valid = jax.random.bernoulli(ks[3], 0.5, (B, L))
    valid = valid.at[0].set(True).at[1].set(False)   # full / empty / ragged
    out_pl = ops.decode_attention(q, k, v, valid, block_l=64,
                                  impl="pallas", interpret=True)
    out_ref = ops.decode_attention(q, k, v, valid, impl="ref")
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out_pl[1]),
                                  np.zeros((H, dh), np.float32))


def test_decode_dispatch_resolution(monkeypatch):
    """Dispatch priority: explicit impl > REPRO_FORCE_REF > interpret
    flag > backend default (ref everywhere but TPU)."""
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    assert ops.resolve_decode_impl(impl="ref") == "ref"
    assert ops.resolve_decode_impl(impl="pallas") == "pallas"
    assert ops.resolve_decode_impl(interpret=True) == "pallas"
    default = ops.resolve_decode_impl()
    assert default == ("pallas" if jax.default_backend() == "tpu"
                       else "ref")
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    assert ops.resolve_decode_impl() == "ref"
    assert ops.resolve_decode_impl(interpret=True) == "ref"
    assert ops.resolve_decode_impl(impl="pallas") == "pallas"  # pin wins
    with pytest.raises(ValueError):
        ops.resolve_decode_impl(impl="dense")


def test_decode_attention_ring_semantics_match_model():
    """Kernel + ring-validity mask == the model's decode_attention maths."""
    B, L, KV, dh, t = 2, 64, 2, 32, 100
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 4, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KV, dh), jnp.float32)
    idx = jnp.arange(L)
    k_pos = t - jnp.mod(t - idx, L)
    valid = (k_pos >= 0) & (k_pos <= t)
    out = ops.decode_attention(q, k, v, jnp.broadcast_to(valid, (B, L)),
                               block_l=32, interpret=True)
    expect = ref.decode_attention(q, k, v, jnp.broadcast_to(valid, (B, L)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # B, H, KV, dh, P, n_log, ps
    (2, 4, 2, 64, 16, 4, 16),
    (1, 8, 1, 128, 8, 8, 8),
    (3, 4, 4, 32, 12, 3, 32),
    (1, 16, 8, 128, 24, 2, 64),
]


def _paged_inputs(B, H, KV, dh, P, n, ps, dtype, key=KEY):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    k_pages = jax.random.normal(ks[1], (P, ps, KV, dh), dtype)
    v_pages = jax.random.normal(ks[2], (P, ps, KV, dh), dtype)
    # Arbitrary page-table contents are legal: repeats (shared prefixes)
    # and page 0 (the engine's trash page) included.
    pages = jax.random.randint(ks[3], (B, n), 0, P)
    valid = jax.random.bernoulli(ks[4], 0.7, (B, n * ps)).at[:, 0].set(True)
    return q, k_pages, v_pages, pages, valid


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(case, dtype):
    B, H, KV, dh, P, n, ps = case
    q, kp, vp, pages, valid = _paged_inputs(B, H, KV, dh, P, n, ps, dtype)
    out = ops.paged_decode_attention(q, kp, vp, pages, valid,
                                     impl="pallas", interpret=True)
    expect = ref.paged_decode_attention(q, kp, vp, pages, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_paged_decode_matches_flat_gather():
    """Walking the page table block-by-block == gathering the rows' pages
    into a flat [B, L] ring and running the FLAT kernel on it."""
    B, H, KV, dh, P, n, ps = 3, 4, 2, 64, 10, 4, 16
    q, kp, vp, pages, valid = _paged_inputs(B, H, KV, dh, P, n, ps,
                                            jnp.float32)
    out = ops.paged_decode_attention(q, kp, vp, pages, valid,
                                     impl="pallas", interpret=True)
    k_flat = kp[pages].reshape(B, n * ps, KV, dh)
    v_flat = vp[pages].reshape(B, n * ps, KV, dh)
    flat = ops.decode_attention(q, k_flat, v_flat, valid, block_l=ps,
                                impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(flat),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_dispatch_paths_agree():
    """Pallas body (interpret) vs the jnp oracle through the SAME
    ``kernels.ops`` dispatcher, including the all-invalid row whose
    contract is zeros."""
    B, H, KV, dh, P, n, ps = 3, 4, 2, 64, 9, 3, 32
    q, kp, vp, pages, valid = _paged_inputs(B, H, KV, dh, P, n, ps,
                                            jnp.float32)
    valid = valid.at[0].set(True).at[1].set(False)   # full / empty / ragged
    out_pl = ops.paged_decode_attention(q, kp, vp, pages, valid,
                                        impl="pallas", interpret=True)
    out_ref = ops.paged_decode_attention(q, kp, vp, pages, valid, impl="ref")
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out_pl[1]),
                                  np.zeros((H, dh), np.float32))


# ---------------------------------------------------------------------------
# rg-lru scan
# ---------------------------------------------------------------------------

RGLRU_CASES = [(2, 512, 256), (1, 256, 128), (4, 128, 384)]


@pytest.mark.parametrize("case", RGLRU_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(case, dtype):
    B, S, W = case
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.8, 0.999).astype(dtype)
    x = jax.random.normal(ks[1], (B, S, W), dtype)
    h0 = jax.random.normal(ks[2], (B, W), jnp.float32)
    y, hl = ops.rglru_scan(a, x, h0, block_s=128, block_w=128, interpret=True)
    ye, hle = ref.rglru_scan(a, x, h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hle),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_rglru_matches_model_associative_scan():
    """Kernel == the model's associative-scan implementation."""
    from repro.models.rglru import rglru_scan as model_scan
    from repro import configs
    cfg = configs.get_reduced("recurrentgemma-2b")
    B, S, W = 2, 128, 128
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.8, 0.999)
    x = jax.random.normal(ks[1], (B, S, W), jnp.float32)
    h0 = jnp.zeros((B, W), jnp.float32)
    y_k, h_k = ops.rglru_scan(a, x, h0, interpret=True)
    y_r, h_r = ref.rglru_scan(a, x, h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

SSM_CASES = [(2, 256, 256, 16), (1, 128, 128, 8), (2, 64, 384, 4)]


@pytest.mark.parametrize("case", SSM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(case, dtype):
    B, S, Di, N = case
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (B, S, Di), dtype)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N), dtype)
    Cc = jax.random.normal(ks[4], (B, S, N), dtype)
    D = jax.random.normal(ks[5], (Di,), jnp.float32)
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    y, hl = ops.ssm_scan(u, delta, A, Bc, Cc, D, h0, block_s=64,
                         block_d=128, interpret=True)
    ye, hle = ref.ssm_scan(u, delta, A, Bc, Cc, D, h0)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **tol)


def test_ssm_scan_chunk_boundary_state_carry():
    """State must carry exactly across sequence-block boundaries."""
    B, S, Di, N = 1, 128, 128, 8
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (B, S, Di), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    D = jnp.zeros((Di,), jnp.float32)
    h0 = jax.random.normal(ks[5], (B, Di, N), jnp.float32)
    outs = [np.asarray(ops.ssm_scan(u, delta, A, Bc, Cc, D, h0,
                                    block_s=bs, block_d=64,
                                    interpret=True)[0])
            for bs in (32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)
