"""Hypothesis property tests on system invariants."""

import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.courier import serialization as ser
from repro.core.fault import RestartPolicy
from repro.data.replay import ReplayServer, TableConfig

# ---------------------------------------------------------------------------
# Courier serialization: loads(dumps(x)) == x for transportable values
# ---------------------------------------------------------------------------

json_like = st.recursive(
    st.none() | st.booleans() | st.integers(-2**31, 2**31)
    | st.floats(allow_nan=False, allow_infinity=False) | st.text(max_size=20),
    lambda children: st.lists(children, max_size=4)
    | st.dictionaries(st.text(max_size=8), children, max_size=4),
    max_leaves=20)


@given(json_like)
@settings(max_examples=50, deadline=None)
def test_serialization_roundtrip(obj):
    out = ser.loads(ser.dumps(obj))
    assert out == obj or _tuplify(out) == _tuplify(obj)


def _tuplify(x):
    if isinstance(x, (list, tuple)):
        return tuple(_tuplify(v) for v in x)
    if isinstance(x, dict):
        return {k: _tuplify(v) for k, v in x.items()}
    return x


@given(st.lists(st.integers(1, 64), min_size=1, max_size=3),
       st.sampled_from([np.float32, np.int32, np.float16]))
@settings(max_examples=25, deadline=None)
def test_serialization_roundtrip_arrays(shape, dtype):
    arr = np.arange(int(np.prod(shape)), dtype=dtype).reshape(shape)
    out = ser.loads(ser.dumps({"x": arr}))["x"]
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


@given(json_like,
       st.lists(st.integers(1, 4096), min_size=0, max_size=4),
       st.integers(0, 64))
@settings(max_examples=50, deadline=None)
def test_scatter_gather_encode_matches_dumps(obj, arr_sizes, slack):
    """The shm ring's scatter-gather path (encode_frames + framed_size +
    write_framed_into) must produce byte-for-byte what ``dumps`` joins,
    for any payload and any buffer slack, and round-trip through loads."""
    payload = {"obj": obj,
               "arrays": [np.arange(n, dtype=np.float32) for n in arr_sizes]}
    frames = ser.encode_frames(payload)
    size = ser.framed_size(frames)
    buf = bytearray(size + slack)
    written = ser.write_framed_into(buf, frames)
    assert written == size
    assert bytes(buf[:written]) == ser.dumps(payload)
    out = ser.loads(bytes(buf[:written]))
    assert _tuplify(out["obj"]) == _tuplify(obj)
    for got, n in zip(out["arrays"], arr_sizes):
        np.testing.assert_array_equal(got, np.arange(n, dtype=np.float32))


@given(st.integers(0, 4096))
@settings(max_examples=20, deadline=None)
def test_write_framed_into_rejects_short_buffers(deficit):
    frames = ser.encode_frames({"x": np.zeros(1024, np.float32)})
    size = ser.framed_size(frames)
    if deficit == 0 or deficit > size:
        return
    with pytest.raises(ValueError, match="needs"):
        ser.write_framed_into(bytearray(size - deficit), frames)


# ---------------------------------------------------------------------------
# RestartPolicy invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 10), st.floats(0.001, 1.0), st.floats(1.0, 4.0),
       st.integers(0, 12))
@settings(max_examples=50, deadline=None)
def test_backoff_monotone_and_capped(max_restarts, base, mult, i):
    p = RestartPolicy(max_restarts=max_restarts, backoff_s=base,
                      backoff_multiplier=mult, max_backoff_s=5.0)
    b1, b2 = p.backoff_for(i), p.backoff_for(i + 1)
    assert 0 < b1 <= 5.0 and b1 <= b2 + 1e-9
    assert p.allows(i) == (i < max_restarts)


def test_negative_budget_always_allows():
    p = RestartPolicy(max_restarts=-1)
    assert all(p.allows(i) for i in (0, 10, 10_000))


# ---------------------------------------------------------------------------
# Replay invariants: size bound, SPI rate limiting, FIFO order
# ---------------------------------------------------------------------------

@given(st.integers(1, 50), st.integers(1, 120))
@settings(max_examples=25, deadline=None)
def test_replay_size_never_exceeds_max(max_size, n_inserts):
    rs = ReplayServer([TableConfig("t", max_size=max_size)])
    for i in range(n_inserts):
        assert rs.insert("t", i, timeout=1.0)
    assert rs.size("t") == min(max_size, n_inserts)


@given(st.integers(1, 30))
@settings(max_examples=20, deadline=None)
def test_replay_fifo_order(n):
    rs = ReplayServer([TableConfig("t", max_size=1000, sampler="fifo")])
    for i in range(n):
        rs.insert("t", i, timeout=1.0)
    out = rs.sample("t", n, timeout=1.0)
    assert out == list(range(n))


def test_replay_spi_blocks_oversampling():
    rs = ReplayServer([TableConfig(
        "t", max_size=100, samples_per_insert=2.0, spi_tolerance=1.0,
        min_size_to_sample=1)])
    rs.insert("t", 0, timeout=1.0)
    # budget = 2*1 + 2*1 = 4 samples
    assert rs.sample("t", 4, timeout=0.5) is not None
    assert rs.sample("t", 1, timeout=0.2) is None  # over budget -> timeout
    rs.insert("t", 1, timeout=1.0)
    assert rs.sample("t", 1, timeout=1.0) is not None  # unblocked


def test_replay_insert_blocks_when_too_far_ahead():
    rs = ReplayServer([TableConfig(
        "t", max_size=100, samples_per_insert=1.0, spi_tolerance=1.0,
        min_size_to_sample=1)])
    ok = [rs.insert("t", i, timeout=0.2) for i in range(10)]
    assert not all(ok)  # the writer hit the rate limiter


# ---------------------------------------------------------------------------
# Sharding rules invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(1, 512), min_size=1, max_size=3))
@settings(max_examples=50, deadline=None)
def test_fit_spec_always_divisible(shape):
    import jax
    from jax.sharding import PartitionSpec
    from repro.sharding.rules import fit_spec
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    from repro.sharding.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    spec = fit_spec(mesh, shape, [("data", "model")] * len(shape))
    assert isinstance(spec, PartitionSpec)
    # every sharded dim is divisible by the axis product
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        assert dim % n == 0


# ---------------------------------------------------------------------------
# Decode-attention kernel vs oracle over random shapes and masks
# ---------------------------------------------------------------------------

@given(st.integers(1, 3),                        # batch
       st.sampled_from([(2, 1), (4, 2), (4, 4)]),  # (heads, kv heads)
       st.sampled_from([32, 64]),                # head dim
       st.sampled_from([64, 96, 128]),           # ring length
       st.floats(0.0, 1.0),                      # valid density
       st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_decode_attention_kernel_matches_oracle(B, hkv, dh, L, density,
                                                seed):
    """Both leaves of the ops.decode_attention dispatcher agree for any
    shape and any validity mask — including rows the density strategy
    drives to all-False, where the contract is zeros."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    H, KV = hkv
    ks = jax.random.split(jax.random.key(seed), 4)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KV, dh), jnp.float32)
    valid = jax.random.bernoulli(ks[3], density, (B, L))
    out_pl = np.asarray(ops.decode_attention(q, k, v, valid, block_l=32,
                                             impl="pallas", interpret=True))
    out_ref = np.asarray(ops.decode_attention(q, k, v, valid, impl="ref"))
    np.testing.assert_allclose(out_pl, out_ref, rtol=1e-5, atol=1e-5)
    dead = ~np.asarray(valid).any(axis=1)
    assert (out_pl[dead] == 0).all()


@given(st.integers(1, 3),                        # batch
       st.sampled_from([(2, 1), (4, 2), (4, 4)]),  # (heads, kv heads)
       st.sampled_from([32, 64]),                # head dim
       st.sampled_from([(6, 2, 16), (10, 4, 8), (5, 3, 32)]),  # (P, n, ps)
       st.floats(0.0, 1.0),                      # valid density
       st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_paged_decode_attention_kernel_matches_oracle(B, hkv, dh, geom,
                                                      density, seed):
    """The paged Pallas walk over an arbitrary page table — repeated
    pages, trash-page (0) entries, any validity mask — matches the
    gather-then-flat-attention oracle; all-invalid rows yield zeros."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    H, KV = hkv
    P, n, ps = geom
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, KV, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, KV, dh), jnp.float32)
    pages = jax.random.randint(ks[3], (B, n), 0, P)
    valid = jax.random.bernoulli(ks[4], density, (B, n * ps))
    out_pl = np.asarray(ops.paged_decode_attention(q, kp, vp, pages, valid,
                                                   impl="pallas",
                                                   interpret=True))
    out_ref = np.asarray(ops.paged_decode_attention(q, kp, vp, pages, valid,
                                                    impl="ref"))
    np.testing.assert_allclose(out_pl, out_ref, rtol=1e-5, atol=1e-5)
    dead = ~np.asarray(valid).any(axis=1)
    assert (out_pl[dead] == 0).all()


# ---------------------------------------------------------------------------
# Optimizer invariants
# ---------------------------------------------------------------------------

@given(st.floats(0.1, 10.0), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_grad_clipping_bounds_update_norm(scale, dim):
    import jax
    import jax.numpy as jnp
    from repro.train import optimizer as opt
    cfg = opt.OptimizerConfig(lr=1.0, warmup_steps=0, total_steps=10,
                              clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((dim,), jnp.float32)}
    grads = {"w": jnp.full((dim,), scale, jnp.float32)}
    state = opt.init_opt_state(params)
    _, _, metrics = opt.apply_updates(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(
        scale * dim ** 0.5, rel=1e-4)
