"""Checkpointing: roundtrip, atomicity, retention, elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint, elastic


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "params": {"w": jax.random.normal(ks[0], (8, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": {"w": jax.random.normal(ks[1], (8, 16)),
                      "b": jnp.zeros((16,))},
                "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.key(0))
    d = str(tmp_path / "ck")
    checkpoint.save(tree, d)
    out = checkpoint.restore(d, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_shape_mismatch(tmp_path):
    tree = _tree(jax.random.key(0))
    d = str(tmp_path / "ck")
    checkpoint.save(tree, d)
    bad = jax.tree.map(lambda x: jnp.zeros((3,)) if x.ndim == 2 else x, tree)
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(d, like=bad)


def test_manager_retention_and_latest(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.key(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]
    step, out = mgr.restore_latest(tree)
    assert step == 4 and out is not None


def test_manager_async_save(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, _tree(jax.random.key(2)))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree(jax.random.key(3)), blocking=True)
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]


def test_publish_metadata_roundtrip(tmp_path):
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(jax.random.key(5))
    meta = {"step": 7, "config_hash": "abc123", "eval": {"loss": 1.25}}
    mgr.publish(7, tree, metadata=meta)
    assert mgr.metadata(7) == meta
    # ModelStore speaks versions over the same directory layout.
    store = checkpoint.ModelStore(str(tmp_path))
    assert store.versions() == [7]
    assert store.latest_version() == 7
    out = store.load_version(7, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_never_deletes_retained_steps(tmp_path):
    """A live-served version is pinned by retain_fn even when ``keep``
    would age it out."""
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=1,
                                       retain_fn=lambda: {1})
    tree = _tree(jax.random.key(6))
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [1, 3]      # 1 pinned, 2 collected


def test_gc_deletes_nothing_when_retain_fn_raises(tmp_path):
    def broken():
        raise ConnectionError("registry down")

    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=1,
                                       retain_fn=broken)
    tree = _tree(jax.random.key(7))
    for s in (1, 2):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [1, 2]      # fail safe: keep everything


def test_half_written_checkpoint_is_skipped(tmp_path):
    """A dir without a manifest (crash mid-write) is invisible to
    ``all_steps``/``restore_latest`` and unloadable as a version."""
    mgr = checkpoint.CheckpointManager(str(tmp_path), keep=5)
    tree = _tree(jax.random.key(8))
    mgr.save(1, tree, blocking=True)
    # Simulate a crash: step 2 has leaves but no manifest.
    half = tmp_path / "step_00000002"
    half.mkdir()
    (half / "leaf_00000.npy").write_bytes(b"garbage")
    assert not checkpoint.is_complete(str(half))
    assert mgr.all_steps() == [1]
    step, out = mgr.restore_latest(tree)
    assert step == 1 and out is not None
    store = checkpoint.ModelStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.load_version(2, like=tree)


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one mesh, restore under a different one (elastic)."""
    from repro.sharding.rules import param_sharding
    tree = {"blocks": {"0": {"mlp": {"w_up": {"kernel":
            jax.random.normal(jax.random.key(4), (2, 4, 8))}}}}}
    d = str(tmp_path / "ck")
    checkpoint.save(tree, d)
    from repro.sharding.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    out = elastic.restore_elastic(d, like=tree, new_mesh=mesh)
    leaf = out["blocks"]["0"]["mlp"]["w_up"]["kernel"]
    np.testing.assert_array_equal(
        np.asarray(leaf),
        np.asarray(tree["blocks"]["0"]["mlp"]["w_up"]["kernel"]))
    assert leaf.sharding.mesh.axis_names == ("data", "model")


def test_self_restoring_node_pattern(tmp_path):
    """Paper §6: a stateful node killed and restarted resumes from its
    checkpoint (scheduler restart + self-restore, no exact recovery)."""
    from repro import core as lp

    class Learner:
        def __init__(self, ckpt_dir):
            self._mgr = checkpoint.CheckpointManager(ckpt_dir, keep=2)
            self._state = {"step": jnp.int32(0)}
            step, restored = self._mgr.restore_latest(self._state)
            self._start = 0
            if restored is not None:
                self._state = restored
                self._start = int(restored["step"])

        def run(self):
            step = self._start
            for _ in range(3):
                step += 1
                self._state = {"step": jnp.int32(step)}
                self._mgr.save(step, self._state, blocking=True)
            if step < 6:
                raise RuntimeError("simulated node failure")
            lp.stop_program()

    p = lp.Program("self-restore")
    p.add_node(lp.PyNode(Learner, str(tmp_path)))
    launcher = lp.ThreadLauncher(
        restart_policy=lp.RestartPolicy(max_restarts=3, backoff_s=0.01))
    launcher.launch(p)
    assert launcher.wait(timeout=30)
    # Crashed once at step 3, restarted, resumed 4..6.
    mgr = checkpoint.CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 6
    assert len([f for f in launcher.failures if not f.fatal]) == 1
