"""Telemetry layer: mergeable histograms, cross-node trace propagation
(single trace across router -> replica hops, including failover), fabric
events, the TelemetryHub collector, and the Chrome trace export.

The fabric tests drive the real Router over the real courier inproc
transport against fake replicas (same harness as tests/test_fabric.py);
the real-engine span path runs in test_engine_spans_and_ttft.
"""

import json
import threading
import time
import uuid

import numpy as np
import pytest

from repro.core import courier, telemetry
from repro.core.discovery import Registry
from repro.core.telemetry import (Histogram, TelemetryHub, TraceContext,
                                  chrome_trace, merge_metric_snapshots,
                                  trace_coverage)
from repro.serve.router import Router


@pytest.fixture(autouse=True)
def clean_buffers():
    """Spans/events land in process-global rings; start every test from
    an empty one so assertions only see their own records."""
    telemetry.spans_buffer().drain()
    telemetry.events_buffer().drain()
    yield
    telemetry.spans_buffer().drain()
    telemetry.events_buffer().drain()


# ---- histograms --------------------------------------------------------------

def _percentile_tolerance():
    # Bucket midpoints sit within (1 + 1/16) of the bucket edges; the
    # worst-case relative error against the exact nearest-rank value is
    # ~6.7%. Assert with headroom.
    return 0.10


def test_histogram_exact_count_sum_min_max():
    h = Histogram("x")
    vals = [3.0, 1.5, 0.25, 1000.0, 7.0]
    for v in vals:
        h.record(v)
    assert h.count == len(vals)
    assert h.total == pytest.approx(sum(vals))
    assert h.mean == pytest.approx(np.mean(vals))
    assert h.vmin == min(vals) and h.vmax == max(vals)
    # Percentiles are clamped to the observed range.
    assert h.percentile(0) >= h.vmin
    assert h.percentile(100) <= h.vmax


def test_histogram_nonpositive_values_bucket_zero():
    h = Histogram("x")
    h.record(0.0)
    h.record(-5.0)
    assert h.count == 2 and h.counts[0] == 2
    assert -5.0 <= h.percentile(50) <= 0.0      # clamped to observed range


def test_histogram_snapshot_roundtrip():
    h = Histogram("x")
    for v in [1e-6, 0.5, 2.0, 3e9]:
        h.record(v)
    back = Histogram.from_snapshot("x", h.snapshot())
    np.testing.assert_array_equal(back.counts, h.counts)
    assert back.count == h.count and back.total == h.total
    assert back.vmin == h.vmin and back.vmax == h.vmax
    assert back.percentile(95) == h.percentile(95)


def test_empty_histogram_is_safe():
    h = Histogram("x")
    assert h.percentile(50) == 0.0 and h.mean == 0.0
    snap = h.snapshot()
    assert snap["count"] == 0 and snap["buckets"] == {}
    assert Histogram.from_snapshot("x", snap).count == 0


try:
    from hypothesis import given, settings, strategies as st

    values = st.lists(st.floats(min_value=1e-6, max_value=1e9,
                                allow_nan=False, allow_infinity=False),
                      min_size=1, max_size=200)

    @given(values, values)
    @settings(max_examples=50, deadline=None)
    def test_histogram_merge_equals_union(a, b):
        """merge(A, B) must be indistinguishable from recording A + B
        into one histogram — the property the collector's roll-up rests
        on."""
        ha, hb, hu = Histogram("a"), Histogram("b"), Histogram("u")
        for v in a:
            ha.record(v)
        for v in b:
            hb.record(v)
        for v in a + b:
            hu.record(v)
        ha.merge(hb)
        np.testing.assert_array_equal(ha.counts, hu.counts)
        assert ha.count == hu.count
        assert ha.total == pytest.approx(hu.total)
        assert ha.vmin == hu.vmin and ha.vmax == hu.vmax
        for q in (50, 95, 99):
            assert ha.percentile(q) == hu.percentile(q)

    @given(values, st.sampled_from([50, 90, 95, 99]))
    @settings(max_examples=50, deadline=None)
    def test_histogram_percentile_within_bucket_error(vals, q):
        """The log2/8-sub-bucket geometry bounds percentile error: the
        reported value is the midpoint of the bucket holding the exact
        nearest-rank sample, so it lands within ~7% of it."""
        h = Histogram("x")
        for v in vals:
            h.record(v)
        exact = sorted(vals)[max(1, int(np.ceil(len(vals) * q / 100.0))) - 1]
        got = h.percentile(q)
        tol = _percentile_tolerance()
        assert got == pytest.approx(exact, rel=tol) or (
            min(vals) <= got <= max(vals)
            and abs(got - exact) <= tol * max(exact, got))
except ImportError:  # pragma: no cover - hypothesis is in the image
    pass


def test_merge_metric_snapshots():
    h1, h2 = Histogram("lat"), Histogram("lat")
    for v in (1.0, 2.0):
        h1.record(v)
    for v in (100.0, 200.0):
        h2.record(v)
    merged = merge_metric_snapshots([
        {"counters": {"reqs": 3}, "gauges": {"depth": 1.0},
         "histograms": {"lat": h1.snapshot()}},
        {"counters": {"reqs": 4, "errs": 1}, "gauges": {"depth": 7.0},
         "histograms": {"lat": h2.snapshot()}},
    ])
    assert merged["counters"] == {"reqs": 7, "errs": 1}
    assert merged["gauges"]["depth"] == 7.0       # last write wins
    lat = merged["histograms"]["lat"]
    assert lat["count"] == 4
    assert lat["mean"] == pytest.approx((1 + 2 + 100 + 200) / 4)
    assert "p50" in lat and "p95" in lat and "p99" in lat


def test_metrics_registry_reset_and_snapshot():
    reg = telemetry.MetricsRegistry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(2.5)
    reg.histogram("h").record(10.0)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == 2.5
    assert snap["histograms"]["h"]["count"] == 1
    reg.reset()
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 0
    assert snap["histograms"]["h"]["count"] == 0


# ---- trace context & spans ---------------------------------------------------

def test_trace_context_wire_roundtrip():
    ctx = telemetry.start_trace()
    back = TraceContext.from_wire(ctx.to_wire())
    assert back == ctx
    assert TraceContext.from_wire("garbage") is None
    child = ctx.child("abc")
    assert child.trace_id == ctx.trace_id and child.parent_id == "abc"


def test_inject_extract_and_idempotency():
    ctx = telemetry.start_trace()
    with telemetry.activate(ctx):
        kwargs = telemetry.inject({"max_new": 4})
        assert telemetry.TRACE_KEY in kwargs
        # Injection never overwrites an explicitly pre-parented envelope.
        pre = dict(kwargs)
        assert telemetry.inject(pre)[telemetry.TRACE_KEY] \
            == kwargs[telemetry.TRACE_KEY]
    got = telemetry.extract(kwargs)
    assert got == ctx and telemetry.TRACE_KEY not in kwargs
    # Unsampled contexts do not propagate.
    with telemetry.activate(telemetry.start_trace(sampled=False)):
        assert telemetry.TRACE_KEY not in telemetry.inject({})


def test_span_nesting_parents_correctly():
    ctx = telemetry.start_trace()
    with telemetry.activate(ctx):
        with telemetry.span("outer"):
            with telemetry.span("inner", k=3):
                pass
    spans = {s["name"]: s for s in telemetry.spans_buffer().drain()}
    assert spans["inner"]["parent"] == spans["outer"]["id"]
    assert spans["outer"]["parent"] is None
    assert spans["inner"]["attrs"]["k"] == 3
    assert spans["outer"]["trace"] == ctx.trace_id


def test_unsampled_span_records_nothing():
    with telemetry.activate(telemetry.start_trace(sampled=False)):
        with telemetry.span("quiet"):
            pass
    assert telemetry.spans_buffer().drain() == []


def test_span_buffer_is_bounded():
    buf = telemetry.SpanBuffer(maxlen=4)
    for i in range(10):
        buf.append({"i": i})
    drained = buf.drain()
    assert [d["i"] for d in drained] == [6, 7, 8, 9]
    assert buf.drain() == []


# ---- cross-node propagation through the fabric -------------------------------

class TracedReplica:
    """EngineServer-shaped fake that records engine-style spans under
    whatever trace context the transport delivered."""

    def __init__(self, fail=False):
        self.fail = fail
        self.calls = 0

    def generate(self, prompt, max_new=None):
        self.calls += 1
        if self.fail:
            raise RuntimeError("engine stopped")
        with telemetry.span("admission"):
            pass
        with telemetry.span("prefill", tokens=len(prompt)):
            time.sleep(0.001)
        with telemetry.span("decode"):
            time.sleep(0.001)
        return np.concatenate([np.asarray(prompt, np.int32), [7]])

    def load(self):
        return {"num_slots": 8, "free_slots": 8, "queue_depth": 0,
                "ewma_us_per_token": 100.0}

    def health(self):
        return {"status": "ok"}

    def telemetry(self):
        return telemetry.telemetry_snapshot(service=self.load())


@pytest.fixture
def fabric():
    registry = Registry(ttl_s=5.0)
    names = []

    def add(replica, load=None, name=None):
        name = name or f"tel-{uuid.uuid4().hex[:8]}"
        courier.inprocess.register(name, replica)
        names.append(name)
        registry.register(name, f"inproc://{name}",
                          load if load is not None else replica.load())
        return name

    yield registry, add
    for name in names:
        courier.inprocess.unregister(name)


def _traced_submit(router, prompt):
    """Client-side half of a sampled request: mint the trace, run submit
    under a context parented on a pre-minted root span id, then record
    the root 'request' span over the measured e2e window."""
    ctx = telemetry.start_trace()
    root_sid = telemetry.new_span_id()
    t0w, t0 = time.time(), time.perf_counter()
    with telemetry.activate(ctx.child(root_sid)):
        out = router.submit(prompt)
    dur = time.perf_counter() - t0
    telemetry.record_span("request", ctx, t0w, dur, span_id=root_sid,
                          root=True)
    return out, ctx, root_sid, t0w, dur


@pytest.mark.parametrize("coalesce", [True, False])
def test_sampled_request_yields_single_nested_trace(fabric, coalesce):
    """One sampled request through a 2-replica fabric produces ONE trace
    whose spans nest correctly across the router -> replica hop."""
    registry, add = fabric
    add(TracedReplica())
    add(TracedReplica())
    with Router(registry, refresh_s=0.05, startup_wait_s=2.0,
                coalesce=coalesce) as router:
        out, ctx, root_sid, _, _ = _traced_submit(
            router, np.arange(4, dtype=np.int32))
    assert out[-1] == 7
    spans = telemetry.spans_buffer().drain()
    assert spans and {s["trace"] for s in spans} == {ctx.trace_id}
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)
    # Router-side spans hang off the client's root span.
    (queue,) = by_name["queue"]
    (dispatch,) = by_name["dispatch"]
    (reply,) = by_name["reply"]
    assert queue["parent"] == root_sid
    assert dispatch["parent"] == root_sid
    assert reply["parent"] == root_sid
    # Replica-side spans nest under the dispatch that carried them.
    for name in ("admission", "prefill", "decode"):
        (s,) = by_name[name]
        assert s["parent"] == dispatch["id"], name
    (root,) = by_name["request"]
    assert root["id"] == root_sid and root["attrs"]["root"] is True


def test_failover_hops_stay_in_one_trace(fabric):
    """A replica dying mid-request adds a second queue/dispatch hop to
    the SAME trace; replica-side spans only hang off the surviving
    dispatch."""
    registry, add = fabric
    # The failing replica advertises the better load -> picked first.
    add(TracedReplica(fail=True),
        load={"num_slots": 8, "free_slots": 8, "queue_depth": 0})
    live = TracedReplica()
    add(live, load={"num_slots": 8, "free_slots": 2, "queue_depth": 3})
    with Router(registry, refresh_s=0.05, startup_wait_s=2.0) as router:
        out, ctx, root_sid, t0w, dur = _traced_submit(
            router, np.arange(4, dtype=np.int32))
    assert out[-1] == 7 and live.calls == 1
    spans = telemetry.spans_buffer().drain()
    assert {s["trace"] for s in spans} == {ctx.trace_id}      # single trace
    queues = [s for s in spans if s["name"] == "queue"]
    dispatches = [s for s in spans if s["name"] == "dispatch"]
    assert len(queues) == 2 and len(dispatches) == 2          # failover hop
    assert {q["attrs"]["attempt"] for q in queues} == {1, 2}
    live_dispatch = [d for d in dispatches
                    if any(s["parent"] == d["id"] for s in spans
                           if s["name"] == "decode")]
    assert len(live_dispatch) == 1
    # The trace explains (almost) every microsecond of the e2e window:
    # fake replicas do ~no work outside their spans, so the union of
    # non-root spans must cover most of it.
    cov = trace_coverage(spans, ctx.trace_id, t0w, dur)
    assert cov > 0.5
    # The drop left a queryable fabric event with a cause.
    events = telemetry.events_buffer().drain()
    kinds = {e["kind"] for e in events}
    assert "replica_dropped" in kinds and "eviction" in kinds
    assert all(e["cause"] for e in events if e["kind"] == "eviction")


def test_router_telemetry_rpc_surfaces_transport_stats(fabric):
    registry, add = fabric
    add(TracedReplica())
    with Router(registry, refresh_s=0.05, startup_wait_s=2.0) as router:
        assert router.submit(np.arange(3, dtype=np.int32))[-1] == 7
        snap = router.telemetry()
    assert "metrics" in snap and "pid" in snap
    transports = snap["service"]["transports"]
    assert transports, "replica transport counters missing"
    (io,) = transports.values()
    assert io["calls"] + io["batched_calls_in_frames"] >= 1


# ---- real engine spans -------------------------------------------------------

def test_engine_spans_and_ttft():
    """A sampled request through the real ServeEngine yields admission /
    prefill / decode spans and a TTFT histogram sample."""
    import jax
    from repro import configs
    from repro.models import transformer
    from repro.serve.engine import ServeEngine

    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, num_slots=2, context_len=24,
                         max_new=4)
    ctx = telemetry.start_trace()
    with telemetry.activate(ctx):
        fut = engine.submit(np.arange(5, dtype=np.int32) % cfg.vocab_size)
    steps = 0
    while not fut.done():
        engine.step()
        steps += 1
        assert steps < 500
    assert fut.result().shape == (9,)
    spans = [s for s in telemetry.spans_buffer().drain()
             if s["trace"] == ctx.trace_id]
    names = {s["name"] for s in spans}
    assert {"admission", "prefill", "decode"} <= names
    hists = telemetry.metrics().snapshot()["histograms"]
    ttft = [k for k in hists if k.startswith("engine.ttft_us.")]
    assert ttft and any(hists[k]["count"] >= 1 for k in ttft)


# ---- collector ---------------------------------------------------------------

class FakeNode:
    """telemetry()-shaped scrape target with a controllable pid."""

    def __init__(self, node, pid, counters=None, spans=(), events=()):
        self._snap = {"node": node, "pid": pid, "time": time.time(),
                      "metrics": {"counters": dict(counters or {}),
                                  "gauges": {}, "histograms": {}},
                      "spans": list(spans), "events": list(events)}
        self.scrapes = 0

    def telemetry(self):
        self.scrapes += 1
        snap = dict(self._snap)
        # Spans drain: only the first scrape carries them.
        if self.scrapes > 1:
            snap["spans"], snap["events"] = [], []
        return snap


def _span(trace, sid, parent, name, ts, dur, node="n"):
    return {"name": name, "trace": trace, "id": sid, "parent": parent,
            "node": node, "ts": ts, "dur": dur, "attrs": {}}


def test_hub_merges_per_pid_and_accumulates_spans(tmp_path):
    sp = _span("t1", "s1", None, "request", 100.0, 1.0)
    a = FakeNode("a", pid=1, counters={"reqs": 5}, spans=[sp],
                 events=[{"kind": "swap", "cause": "v2", "node": "a",
                          "ts": 100.5, "attrs": {}}])
    # Same pid as a (thread-launched sibling sharing the registry): its
    # counters must NOT double the merge.
    b = FakeNode("b", pid=1, counters={"reqs": 5})
    c = FakeNode("c", pid=2, counters={"reqs": 2})
    hub = TelemetryHub(targets=[a, b, c], out_dir=str(tmp_path))
    assert hub.scrape_once() == 3
    assert hub.scrape_once() == 3                  # spans don't duplicate
    merged = hub.merged_metrics()
    assert merged["counters"]["reqs"] == 7         # 5 (pid 1, once) + 2
    assert len(hub.spans()) == 1
    assert hub.events()[0]["kind"] == "swap"
    # Export: merged snapshot + Perfetto-loadable trace.
    snap = json.loads((tmp_path / "telemetry.json").read_text())
    assert snap["merged"]["counters"]["reqs"] == 7
    assert snap["hub"]["scrapes"] >= 3
    trace = json.loads((tmp_path / "trace.json").read_text())
    evs = trace["traceEvents"]
    assert any(e["ph"] == "X" and e["name"] == "request" for e in evs)
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    assert any(e["ph"] == "i" and "swap" in e["name"] for e in evs)


def test_hub_scrapes_registry_replicas(fabric):
    registry, add = fabric
    rep = TracedReplica()
    add(rep)
    hub = TelemetryHub(registry=registry)
    assert hub.scrape_once() >= 1
    # The replica's process registry reached the hub (pid-keyed).
    assert hub.snapshot()["hub"]["scrapes"] >= 1
    hub.close()


def test_hub_survives_dead_targets():
    class Dead:
        def telemetry(self):
            raise ConnectionError("gone")

    hub = TelemetryHub(targets=[Dead(), FakeNode("ok", pid=9)])
    assert hub.scrape_once() == 1
    assert hub.snapshot()["hub"]["scrape_errors"] == 1


# ---- chrome trace & coverage -------------------------------------------------

def test_chrome_trace_maps_nodes_to_pids_and_traces_to_tids():
    spans = [_span("t1", "s1", None, "a", 1.0, 0.5, node="router"),
             _span("t1", "s2", "s1", "b", 1.1, 0.2, node="engine"),
             _span("t2", "s3", None, "a", 2.0, 0.1, node="router")]
    out = chrome_trace(spans)
    evs = [e for e in out["traceEvents"] if e["ph"] == "X"]
    pids = {e["args"]["trace"]: e["tid"] for e in evs}
    assert pids["t1"] != pids["t2"]               # traces on separate rows
    nodes = {e["pid"] for e in evs}
    assert len(nodes) == 2                        # router + engine
    json.dumps(out)                               # serializable as-is


def test_trace_coverage_unions_overlaps_and_skips_root():
    spans = [
        _span("t", "root", None, "request", 0.0, 10.0),
        _span("t", "a", "root", "queue", 0.0, 4.0),
        _span("t", "b", "root", "dispatch", 3.0, 4.0),   # overlaps a
        _span("t", "c", "root", "decode", 8.0, 1.0),
        _span("other", "x", None, "noise", 0.0, 10.0),
    ]
    spans[0]["attrs"]["root"] = True
    cov = trace_coverage(spans, "t", 0.0, 10.0)
    assert cov == pytest.approx(0.8)              # [0,7) + [8,9) = 8 of 10
    assert trace_coverage(spans, "t", 0.0, 0.0) == 0.0


# ---- structured logging ------------------------------------------------------

def test_node_logger_prefixes_and_records_events(capsys):
    log = telemetry.get_logger("worker-3")
    log.info("starting", step=7)
    log.error("boom", reason="test")
    err = capsys.readouterr().err
    assert "[worker-3] INFO: starting (step=7)" in err
    assert "[worker-3] ERROR: boom" in err
    events = telemetry.events_buffer().drain()
    assert [e["kind"] for e in events] == ["error"]
    assert events[0]["cause"] == "boom" and events[0]["node"] == "worker-3"


def test_node_logger_exception_appends_traceback(capsys):
    log = telemetry.get_logger("w")
    try:
        raise ValueError("kaput")
    except ValueError:
        log.exception("worker crashed")
    err = capsys.readouterr().err
    assert "worker crashed" in err and "ValueError: kaput" in err
    (event,) = telemetry.events_buffer().drain()
    assert event["kind"] == "error"


# ---- hot-path sanity ---------------------------------------------------------

def test_unsampled_hot_path_is_cheap():
    """No trace context active: inject is a dict passthrough and span a
    no-op — the invariant the <= 1.03x bench gate rests on."""
    kwargs = {"max_new": 4}
    assert telemetry.inject(kwargs) is kwargs
    h = telemetry.metrics().histogram("bench.sanity")
    t0 = time.perf_counter()
    n = 20000
    for _ in range(n):
        h.record(12.5)
    per_record = (time.perf_counter() - t0) / n
    assert per_record < 50e-6      # generous: just catches O(n) mistakes


def test_concurrent_recording_is_safe():
    h = telemetry.metrics().histogram("concurrent.h")
    c = telemetry.metrics().counter("concurrent.c")

    def work():
        for _ in range(1000):
            h.record(3.0)
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == int(h.counts.sum())
    assert c.value <= 4000 and c.value > 0
