"""Integration tests: every example program runs to completion (paper §3.2
— test launcher waits for the system to perform its task and terminate)."""

import importlib.util
import os
import sys

import pytest

from repro import core as lp

EXAMPLES = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "examples"))


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"example_{name}", os.path.join(EXAMPLES, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_runs():
    mod = _load("quickstart")
    lp.launch_and_wait(mod.make_program(), timeout_s=30)


def test_parameter_server_topologies():
    mod = _load("parameter_server")
    for mode in ("single", "replicated", "cached"):
        lp.launch_and_wait(mod.build(mode, num_requesters=2, seconds=0.2),
                           timeout_s=30)


def test_mapreduce_counts_words(tmp_path):
    mod = _load("mapreduce")
    text = "a b c a b a\n"
    paths = []
    for i in range(2):
        p = tmp_path / f"in{i}.txt"
        p.write_text(text * 5)
        paths.append(str(p))
    out = str(tmp_path / "out.txt")
    expected = 2 * 5 * 6
    lp.launch_and_wait(mod.build(paths, out, expected), timeout_s=60)
    counts = {}
    with open(out) as f:
        for line in f:
            w, c = line.split()
            counts[w] = counts.get(w, 0) + int(c)
    assert counts == {"a": 30, "b": 20, "c": 10}


def test_evolution_strategies_improves():
    mod = _load("evolution_strategies")
    import numpy as np
    fits = []

    class Evolver(mod.Evolver):
        def run(self):
            super().run()

    lp.launch_and_wait(mod.build(num_evaluators=3, generations=8),
                       timeout_s=300)


def test_actor_learner_runs():
    mod = _load("actor_learner")
    lp.launch_and_wait(mod.build(num_actors=2, steps=20), timeout_s=300)


def test_train_lm_end_to_end(tmp_path):
    from repro.launch.train import LM_TINY, build_program
    import dataclasses
    cfg = dataclasses.replace(LM_TINY, num_layers=2, d_model=64, d_ff=128)
    program = build_program(cfg, steps=12, ckpt_dir=str(tmp_path),
                            batch_size=8, seq_len=32, with_eval=False)
    lp.launch_and_wait(program, timeout_s=600)
    # learner checkpointed its final state
    from repro.ckpt.checkpoint import CheckpointManager
    assert CheckpointManager(str(tmp_path)).latest_step() == 12


@pytest.mark.parametrize("mode", ["continuous", "lockstep"])
def test_serve_lm_end_to_end(mode, tmp_path):
    from repro import configs
    from repro.launch.serve import build_program
    cfg = configs.get_reduced("qwen2-1.5b")
    meter_json = str(tmp_path / "serve_meter.json")
    program = build_program(cfg, num_clients=2, requests_per_client=2,
                            prompt_len=8, max_new=4, mode=mode,
                            meter_json=meter_json)
    lp.launch_and_wait(program, timeout_s=600)
    import json
    summary = json.load(open(meter_json))
    assert summary["count"] == 4
    assert summary["p95_ms"] >= summary["p50_ms"] > 0


def test_serve_lm_fabric_end_to_end(tmp_path):
    """Replicated fabric: Registry -> Router -> 2 EngineServers serves
    every request, and the meter summary is namespaced by router."""
    from repro import configs
    from repro.launch.serve import build_program
    cfg = configs.get_reduced("qwen2-1.5b")
    meter_json = str(tmp_path / "fabric_meter.json")
    program = build_program(cfg, num_clients=2, requests_per_client=2,
                            prompt_len=8, max_new=4, replicas=2, routers=1,
                            meter_json=meter_json)
    lp.launch_and_wait(program, timeout_s=600)
    import json
    summary = json.load(open(meter_json))
    assert summary["count"] == 4
    assert summary["p95_ms"] >= summary["p50_ms"] > 0
    (source,) = summary["per_source"]
    assert "Router" in source
    assert summary["per_source"][source]["count"] == 4


def test_serve_lm_failover_demo(tmp_path, capsys):
    """The --kill-after demo: one replica dies mid-run; every request is
    still served (failover onto the sibling, zero lost)."""
    from repro import configs
    from repro.launch.serve import build_program
    cfg = configs.get_reduced("qwen2-1.5b")
    meter_json = str(tmp_path / "failover_meter.json")
    program = build_program(cfg, num_clients=2, requests_per_client=3,
                            prompt_len=8, max_new=4, replicas=2, routers=1,
                            meter_json=meter_json, kill_after=1,
                            registry_ttl_s=1.0, heartbeat_s=0.2)
    lp.launch_and_wait(program, timeout_s=600)
    import json
    summary = json.load(open(meter_json))
    assert summary["count"] == 6          # zero lost
    # Guard against a vacuous pass: the kill is count-triggered (after
    # the first served request), so it must have landed mid-run.
    assert "fault: kill -> target 0 fired" in capsys.readouterr().out


def test_serve_lm_rollout_demo(tmp_path, capsys):
    """The --rollout-after demo: mid-run the fleet rolls v0 -> v1 one
    replica at a time; every request is served (zero lost) and the
    rollout promotes."""
    import json

    import jax

    from repro import configs
    from repro.ckpt.checkpoint import ModelStore, config_hash
    from repro.launch.serve import build_program
    from repro.models import transformer
    cfg = configs.get_reduced("qwen2-1.5b")
    store_dir = str(tmp_path / "store")
    store = ModelStore(store_dir)
    for v in (0, 1):
        store.publish_version(
            v, transformer.init_params(cfg, jax.random.key(v)),
            metadata={"step": v, "config_hash": config_hash(cfg)})
    meter_json = str(tmp_path / "rollout_meter.json")
    program = build_program(cfg, num_clients=2, requests_per_client=3,
                            prompt_len=8, max_new=4, replicas=2, routers=1,
                            meter_json=meter_json, registry_ttl_s=2.0,
                            heartbeat_s=0.1, store_dir=store_dir,
                            model_version=0, rollout=1, rollout_after=1)
    lp.launch_and_wait(program, timeout_s=600)
    summary = json.load(open(meter_json))
    assert summary["count"] == 6          # zero lost across the roll
    out = capsys.readouterr().out
    assert "rollout: promoted -> v1" in out


def test_meter_hold_gates_stop():
    """A Meter stop-hold delays program stop past the last served
    request until released — the rollout demo relies on this so a
    late-scheduled RolloutDriver never races program teardown (its
    registry lookup would find every courier service unregistered)."""
    import threading

    from repro.core.nodes.base import WorkerContext, set_current_context
    from repro.launch.serve import Meter

    stops = []
    set_current_context(WorkerContext(
        node_name="meter", stop_event=threading.Event(),
        stop_program_fn=lambda: stops.append(True)))
    try:
        m = Meter(2, holds=1)
        m.record(0.01, 4)
        m.record(0.01, 4)
        assert not stops              # count reached, hold still pending
        m.release("rollout")
        assert len(stops) == 1        # hold dropped -> stop fires

        m2 = Meter(1, holds=1)        # release-before-done: record stops
        m2.release("rollout")
        assert len(stops) == 1
        m2.record(0.01, 4)
        assert len(stops) == 2
    finally:
        set_current_context(None)
