"""Distributed building blocks on a small host-device mesh: sharding rules,
collective matmul, gradient compression, dry-run cells at reduced scale.

These tests spawn a subprocess with XLA_FLAGS for 8 placeholder devices
(the main test process must keep seeing 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.sharding.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
"""


def _run(body: str) -> str:
    code = _PRELUDE + textwrap.dedent(body)
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # Propagate the platform pin: without it jax probes for accelerators
    # in the stripped subprocess env (TPU metadata fetch retries cost
    # minutes per test on CPU-only hosts).
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_param_sharding_rules_shard_big_weights():
    out = _run("""
    from repro import configs
    from repro.models import transformer
    from repro.sharding.rules import param_sharding, spec_for_path
    cfg = configs.get("qwen3-8b")
    shapes = transformer.param_shapes(cfg)
    sh = param_sharding(shapes, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    sharded = 0
    for path, s in flat:
        if any(a is not None for a in s.spec):
            sharded += 1
    print("SHARDED", sharded, len(flat))
    """)
    sharded, total = map(int, out.split()[1:3])
    assert sharded >= total * 0.5  # most tensors sharded


def test_optimizer_moments_share_param_sharding():
    out = _run("""
    from repro import configs
    from repro.sharding.rules import param_sharding
    from repro.train.train_step import train_state_shapes
    cfg = configs.get("qwen2-1.5b")
    params, opt = train_state_shapes(cfg)
    psh = param_sharding(params, mesh)
    osh = param_sharding(opt, mesh)
    # every m/v moment gets the same spec as its parameter
    ok = True
    pf = dict(jax.tree_util.tree_flatten_with_path(psh)[0])
    for path, s in jax.tree_util.tree_flatten_with_path(osh["m"])[0]:
        pspec = [v for k, v in pf.items() if tuple(k) == tuple(path)]
        if pspec and pspec[0].spec != s.spec:
            ok = False
    print("MOMENTS_OK", ok)
    """)
    assert "MOMENTS_OK True" in out


def test_collective_matmul_matches_einsum():
    out = _run("""
    from repro.sharding.collective_matmul import collective_matmul
    key = jax.random.key(0)
    B, S, D, F = 2, 8, 32, 64
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (D, F), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, "model")))
    ws = jax.device_put(w, NamedSharding(mesh, P(None, "model")))
    y = collective_matmul(xs, ws, mesh)
    expect = x @ w
    err = float(jnp.abs(y - expect).max() / jnp.abs(expect).max())
    print("ERR", err)
    """)
    assert float(out.split()[1]) < 1e-2  # bf16 accumulate inside


def test_grad_compression_cross_pod():
    out = _run("""
    import os
    from repro.train.grad_compression import compress_reduce_pod
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 64.0}
    # replicate across pods with different values -> psum averages them
    def make(v):
        return {"w": g["w"] + v}
    # place replicated
    gs = jax.device_put(g, NamedSharding(mesh3, P()))
    red, err = compress_reduce_pod(gs, None, mesh3, method="int8_ef")
    expect = g["w"]  # identical on both pods -> average == itself
    delta = float(jnp.abs(red["w"] - expect).max())
    maxerr = float(jnp.abs(err["w"]).max())
    print("DELTA", delta, "ERRSTATE", maxerr)
    """)
    parts = out.split()
    assert float(parts[1]) < 1e-2       # quantization error small
    assert float(parts[3]) < 1e-2       # error-feedback state bounded


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("mixtral-8x7b", "decode_32k"),
    ("falcon-mamba-7b", "long_500k"),
])
def test_dryrun_cell_compiles_on_small_mesh(arch, shape):
    """The dry-run machinery end-to-end on an 8-device placeholder mesh,
    reduced shapes (full 512-dev meshes are exercised by the real dryrun)."""
    out = _run(f"""
    import dataclasses
    from repro import configs
    from repro.launch import cells as cells_lib
    from repro.models.config import ShapeConfig
    cfg = configs.get_reduced("{arch}")
    base = cells_lib.SHAPES["{shape}"]
    small = ShapeConfig(base.name, base.kind, seq_len=256, global_batch=4)
    plan = cells_lib.plan_cell(cfg, small, mesh)
    cell = cells_lib.build_cell(cfg, small, mesh, plan=plan)
    compiled = cells_lib.lower_cell(cell, mesh).compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0]
    print("OK", ma.temp_size_in_bytes, float(ca.get("flops", 0.0)))
    """)
    assert out.startswith("OK")
    assert float(out.split()[2]) > 0  # nonzero flops counted
