"""Setup-phase semantics: graph construction, groups, handles, addresses."""

import pytest

from repro import core as lp
from repro.core.addressing import Address
from repro.core.resources import DEFAULT_GROUP


class Svc:
    def ping(self):
        return "pong"


class Other:
    pass


def test_add_node_returns_handle():
    p = lp.Program("t")
    h = p.add_node(lp.CourierNode(Svc))
    assert isinstance(h, lp.Handle)


def test_pynode_has_no_handle():
    p = lp.Program("t")
    assert p.add_node(lp.PyNode(Svc)) is None


def test_edges_follow_handles():
    p = lp.Program("t")
    h1 = p.add_node(lp.CourierNode(Svc))
    h2 = p.add_node(lp.CourierNode(Svc))
    consumer = lp.CourierNode(Svc, [h1, {"x": h2}])
    p.add_node(consumer)
    edges = p.edges()
    assert len(edges) == 2
    assert all(c is consumer for c, _ in edges)


def test_groups_assign_nodes():
    p = lp.Program("t")
    with p.group("a"):
        p.add_node(lp.CourierNode(Svc))
        p.add_node(lp.CourierNode(Svc))
    p.add_node(lp.CourierNode(Svc))
    assert len(p.groups["a"].nodes) == 2
    assert len(p.groups[DEFAULT_GROUP].nodes) == 1


def test_groups_cannot_nest():
    p = lp.Program("t")
    with pytest.raises(RuntimeError):
        with p.group("a"):
            with p.group("b"):
                pass


def test_group_requires_same_node_type():
    p = lp.Program("t")
    with pytest.raises(TypeError):
        with p.group("a"):
            p.add_node(lp.CourierNode(Svc))
            p.add_node(lp.PyNode(Svc))


def test_unresolved_address_raises_on_dereference():
    p = lp.Program("t")
    h = p.add_node(lp.CourierNode(Svc))
    with pytest.raises(RuntimeError, match="before launch"):
        h.dereference()


def test_address_resolves_once():
    a = Address("x")
    a.resolve("grpc://1.2.3.4:1")
    with pytest.raises(RuntimeError):
        a.resolve("grpc://1.2.3.4:2")


def test_validate_rejects_foreign_handles():
    p1 = lp.Program("a")
    h = p1.add_node(lp.CourierNode(Svc))
    p2 = lp.Program("b")
    p2.add_node(lp.CourierNode(Svc, h))
    with pytest.raises(ValueError, match="does not"):
        p2.validate()


def test_dryrun_launcher_reports_topology():
    p = lp.Program("t")
    with p.group("producer"):
        h1 = p.add_node(lp.CourierNode(Svc))
        h2 = p.add_node(lp.CourierNode(Svc))
    with p.group("consumer"):
        p.add_node(lp.CourierNode(Svc, [h1, h2]))
    launcher = lp.DryRunLauncher()
    launcher.launch(p)
    rep = launcher.report()
    assert len(rep.nodes) == 3
    assert len(rep.edges) == 2
    assert set(rep.groups) == {"producer", "consumer"}
    assert sum(rep.executables.values()) == 3
