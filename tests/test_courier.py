"""Courier RPC layer: gRPC server/client, futures, errors, serialization."""

import numpy as np
import pytest

from repro.core import courier
from repro.core.courier import serialization as ser
from repro.core.courier.server import CourierServer


class Service:
    def __init__(self):
        self.calls = 0

    def add(self, a, b=0):
        self.calls += 1
        return a + b

    def echo_array(self, x):
        return x * 2

    def boom(self):
        raise ValueError("intentional")

    def run(self):  # must NOT be exposed
        raise AssertionError("run must not be callable remotely")

    def _private(self):
        return "secret"


@pytest.fixture
def served():
    srv = CourierServer(Service())
    srv.start()
    yield courier.client_for(srv.endpoint)
    srv.stop()


def test_basic_call(served):
    assert served.add(2, b=3) == 5


def test_numpy_roundtrip(served):
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    np.testing.assert_array_equal(served.echo_array(x), x * 2)


def test_jax_arrays_transport(served):
    import jax.numpy as jnp
    out = served.echo_array(jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4,)))


def test_futures(served):
    futs = [served.futures.add(i, b=1) for i in range(8)]
    assert [f.result(timeout=10) for f in futs] == list(range(1, 9))


def test_remote_error_reraises(served):
    with pytest.raises(courier.RemoteError, match="intentional"):
        served.boom()


def test_run_and_private_not_exposed(served):
    with pytest.raises(courier.RemoteError):
        served.run()          # server refuses to expose run()
    with pytest.raises(AttributeError):
        served._private()     # client refuses private names outright


def test_inprocess_channel_matches_grpc_api():
    courier.inprocess.register("svc", Service())
    client = courier.client_for("inproc://svc")
    assert client.add(1, b=2) == 3
    assert client.futures.add(4, b=4).result(timeout=5) == 8


def test_serialization_roundtrip_nested():
    obj = {"a": [1, (2.5, "x")], "b": np.ones((2, 2))}
    out = ser.loads(ser.dumps(obj))
    assert out["a"][0] == 1 and out["a"][1][1] == "x"
    np.testing.assert_array_equal(out["b"], np.ones((2, 2)))
