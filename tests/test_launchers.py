"""Launch/execution phases: thread + process launchers, restarts, stop."""

import os
import tempfile
import time

import pytest

from repro import core as lp


class Range:
    def __init__(self, lo, hi):
        self._lo, self._hi = lo, hi

    def get(self):
        return list(range(self._lo, self._hi))


class SumConsumer:
    def __init__(self, producers, out_path):
        self._producers = producers
        self._out = out_path

    def run(self):
        total = sum(sum(p.get()) for p in self._producers)
        with open(self._out, "w") as f:
            f.write(str(total))
        lp.stop_program()


def _producer_consumer(out_path):
    p = lp.Program("pc")
    with p.group("producer"):
        h1 = p.add_node(lp.CourierNode(Range, 0, 10))
        h2 = p.add_node(lp.CourierNode(Range, 10, 20))
    with p.group("consumer"):
        p.add_node(lp.CourierNode(SumConsumer, [h1, h2], out_path))
    return p


def _read(path):
    with open(path) as f:
        return int(f.read())


def test_thread_launcher_inproc():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "out")
        lp.launch_and_wait(_producer_consumer(out), timeout_s=20)
        assert _read(out) == sum(range(20))


def test_thread_launcher_grpc():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "out")
        lp.launch_and_wait(_producer_consumer(out), timeout_s=30,
                           force_grpc=True)
        assert _read(out) == sum(range(20))


def test_process_launcher():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "out")
        launcher = lp.ProcessLauncher()
        launcher.launch(_producer_consumer(out))
        assert launcher.wait(timeout=60)
        assert _read(out) == sum(range(20))


class FlakyOnce:
    def __init__(self, marker):
        self._marker = marker

    def run(self):
        if not os.path.exists(self._marker):
            open(self._marker, "w").close()
            raise RuntimeError("first attempt crashes")
        lp.stop_program()


def test_thread_restart_policy_recovers():
    with tempfile.TemporaryDirectory() as d:
        p = lp.Program("flaky")
        p.add_node(lp.PyNode(FlakyOnce, os.path.join(d, "m")))
        launcher = lp.ThreadLauncher(
            restart_policy=lp.RestartPolicy(max_restarts=2, backoff_s=0.01))
        launcher.launch(p)
        assert launcher.wait(timeout=20)
        assert len(launcher.failures) == 1
        assert not launcher.failures[0].fatal


def test_process_restart_policy_recovers():
    with tempfile.TemporaryDirectory() as d:
        p = lp.Program("flaky")
        p.add_node(lp.PyNode(FlakyOnce, os.path.join(d, "m")))
        launcher = lp.ProcessLauncher(
            restart_policy=lp.RestartPolicy(max_restarts=2, backoff_s=0.01))
        launcher.launch(p)
        assert launcher.wait(timeout=60)
        assert len(launcher.failures) == 1 and not launcher.failures[0].fatal


class AlwaysDies:
    def run(self):
        raise RuntimeError("nope")


def test_fatal_after_budget_stops_program():
    p = lp.Program("dead")
    p.add_node(lp.PyNode(AlwaysDies))
    launcher = lp.ThreadLauncher(
        restart_policy=lp.RestartPolicy(max_restarts=1, backoff_s=0.01))
    launcher.launch(p)
    assert launcher.wait(timeout=20)
    assert any(f.fatal for f in launcher.failures)


def test_test_launcher_raises_on_fatal():
    p = lp.Program("dead")
    p.add_node(lp.PyNode(AlwaysDies))
    with pytest.raises(lp.ProgramTestError):
        lp.launch_and_wait(p, timeout_s=20)


class Waits:
    def run(self):
        lp.get_current_context().wait_for_stop(30)


def test_stop_propagates_to_waiting_services():
    p = lp.Program("w")
    p.add_node(lp.PyNode(Waits))
    launcher = lp.ThreadLauncher()
    launcher.launch(p)
    time.sleep(0.1)
    launcher.stop()
    assert launcher.wait(timeout=10)


def test_resources_for_unknown_group_rejected():
    p = lp.Program("t")
    p.add_node(lp.PyNode(Waits))
    with pytest.raises(ValueError, match="unknown groups"):
        lp.ThreadLauncher().launch(p, resources={"nope": {}})
