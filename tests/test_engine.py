"""ServeEngine invariants: slotted-KV-cache admission, retirement, and
per-request delivery semantics (continuous batching)."""

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import transformer
from repro.serve import decode as serve_lib
from repro.serve.engine import ServeEngine

CFG = configs.get_reduced("qwen2-1.5b")
L = 24          # engine context (slot ring length)
MAX_NEW = 4


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(CFG, jax.random.key(0))


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, n).astype(np.int32)
            for n in lens]


def _run(engine, futs, max_steps=500):
    steps = 0
    while not all(f.done() for f in futs):
        engine.step()
        steps += 1
        assert steps < max_steps, "engine made no progress"


def _solo(params, prompt, max_new=MAX_NEW):
    import jax.numpy as jnp
    return np.asarray(serve_lib.generate(
        CFG, params, jnp.asarray(prompt[None]), max_new=max_new,
        context_len=L))[0]


def test_engine_matches_solo_serving(params):
    """A request decoded in a shared slot pool must equal the same prompt
    served alone (same ring length): slots are isolated."""
    engine = ServeEngine(CFG, params, num_slots=3, context_len=L,
                         max_new=MAX_NEW)
    prompts = _prompts([5, 9, 7, 5, 12])
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        out = f.result()
        assert out.shape == (len(p) + MAX_NEW,)
        np.testing.assert_array_equal(out, _solo(params, p))


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "falcon-mamba-7b"])
def test_engine_serves_recurrent_archs(arch):
    """Exact-length admission keeps recurrent state (RG-LRU / Mamba)
    correct — no pad tokens ever enter a prefill."""
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9)]
    engine = ServeEngine(cfg, params, num_slots=2, context_len=L,
                         max_new=3)
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    import jax.numpy as jnp
    for p, f in zip(prompts, futs):
        solo = np.asarray(serve_lib.generate(
            cfg, params, jnp.asarray(p[None]), max_new=3,
            context_len=L))[0]
        np.testing.assert_array_equal(f.result(), solo)


def test_slot_reuse_and_full_pool_queues(params):
    """More requests than slots: the pool queues (never errors), retired
    slots are reused, and occupancy never exceeds the pool."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW)
    prompts = _prompts([5] * 7, seed=2)
    futs = [engine.submit(p) for p in prompts]
    assert engine.stats()["queue_depth"] == 7     # nothing admitted yet
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        assert f.result().shape == (len(p) + MAX_NEW,)
    s = engine.stats()
    assert s["admitted"] == 7
    assert s["retired"] == 7
    assert s["peak_occupancy"] <= 2
    assert s["free_slots"] == 2
    assert s["queue_depth"] == 0


def test_interleaved_admission_preserves_inflight_decode(params):
    """Admitting B mid-flight (prefill + slot write between decode steps)
    must not perturb A's in-flight rows, and vice versa."""
    a, b = _prompts([6, 10], seed=3)
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW)
    fa = engine.submit(a)
    engine.step()
    engine.step()                                 # A is mid-decode
    fb = engine.submit(b)
    _run(engine, [fa, fb])
    np.testing.assert_array_equal(fa.result(), _solo(params, a))
    np.testing.assert_array_equal(fb.result(), _solo(params, b))


def test_per_request_failure_delivery(params):
    """A request that cannot fit fails its own future; neighbours in the
    same step complete untouched."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW)
    good1 = engine.submit(_prompts([5], seed=4)[0])
    bad = engine.submit(np.arange(L, dtype=np.int32))   # L + max_new > L
    good2 = engine.submit(_prompts([7], seed=5)[0])
    with pytest.raises(ValueError, match="context_len"):
        bad.result(timeout=5)
    _run(engine, [good1, good2])
    assert good1.result().shape == (5 + MAX_NEW,)
    assert good2.result().shape == (7 + MAX_NEW,)
    assert engine.stats()["failed"] == 0          # rejected pre-queue
    assert engine.stats()["retired"] == 2


def test_eos_retires_slot_immediately(params):
    """EOS retirement: with eos_id set to the token the model actually
    emits first, the sequence retires after one generated token and its
    slot frees for the next request."""
    prompt = _prompts([6], seed=6)[0]
    probe = ServeEngine(CFG, params, num_slots=1, context_len=L,
                        max_new=MAX_NEW)
    f = probe.submit(prompt)
    _run(probe, [f])
    first_tok = int(f.result()[len(prompt)])

    engine = ServeEngine(CFG, params, num_slots=1, context_len=L,
                         max_new=MAX_NEW, eos_id=first_tok)
    f1 = engine.submit(prompt)
    f2 = engine.submit(_prompts([9], seed=7)[0])
    _run(engine, [f1, f2])
    out = f1.result()
    assert out.shape == (len(prompt) + 1,)        # stopped at EOS
    assert out[-1] == first_tok
    s = engine.stats()
    assert s["retired"] == 2 and s["free_slots"] == 1


def test_stop_fails_pending_requests(params):
    engine = ServeEngine(CFG, params, num_slots=1, context_len=L,
                         max_new=MAX_NEW)
    fut = engine.submit(_prompts([5], seed=8)[0])
    engine.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        fut.result(timeout=5)
    with pytest.raises(RuntimeError, match="stopped"):
        engine.submit(_prompts([5], seed=9)[0]).result(timeout=5)


def test_background_loop_serves(params):
    """The daemon decode loop: submit from this thread, replies stream
    back per request through the futures."""
    with ServeEngine(CFG, params, num_slots=2, context_len=L,
                     max_new=MAX_NEW) as engine:
        prompts = _prompts([5, 8, 11], seed=10)
        futs = [engine.submit(p) for p in prompts]
        for p, f in zip(prompts, futs):
            assert f.result(timeout=120).shape == (len(p) + MAX_NEW,)
    assert engine.stats()["retired"] == 3
