"""ServeEngine invariants: slotted-KV-cache admission, retirement, and
per-request delivery semantics (continuous batching)."""

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import transformer
from repro.serve import decode as serve_lib
from repro.serve.engine import ServeEngine

CFG = configs.get_reduced("qwen2-1.5b")
L = 24          # engine context (slot ring length)
MAX_NEW = 4


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(CFG, jax.random.key(0))


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, n).astype(np.int32)
            for n in lens]


def _run(engine, futs, max_steps=500):
    steps = 0
    while not all(f.done() for f in futs):
        engine.step()
        steps += 1
        assert steps < max_steps, "engine made no progress"


def _solo(params, prompt, max_new=MAX_NEW):
    import jax.numpy as jnp
    return np.asarray(serve_lib.generate(
        CFG, params, jnp.asarray(prompt[None]), max_new=max_new,
        context_len=L))[0]


def test_engine_matches_solo_serving(params):
    """A request decoded in a shared slot pool must equal the same prompt
    served alone (same ring length): slots are isolated."""
    engine = ServeEngine(CFG, params, num_slots=3, context_len=L,
                         max_new=MAX_NEW)
    prompts = _prompts([5, 9, 7, 5, 12])
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        out = f.result()
        assert out.shape == (len(p) + MAX_NEW,)
        np.testing.assert_array_equal(out, _solo(params, p))


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "falcon-mamba-7b"])
def test_engine_serves_recurrent_archs(arch):
    """Exact-length admission keeps recurrent state (RG-LRU / Mamba)
    correct — no pad tokens ever enter a prefill."""
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9)]
    engine = ServeEngine(cfg, params, num_slots=2, context_len=L,
                         max_new=3)
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    import jax.numpy as jnp
    for p, f in zip(prompts, futs):
        solo = np.asarray(serve_lib.generate(
            cfg, params, jnp.asarray(p[None]), max_new=3,
            context_len=L))[0]
        np.testing.assert_array_equal(f.result(), solo)


def test_slot_reuse_and_full_pool_queues(params):
    """More requests than slots: the pool queues (never errors), retired
    slots are reused, and occupancy never exceeds the pool."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW)
    prompts = _prompts([5] * 7, seed=2)
    futs = [engine.submit(p) for p in prompts]
    assert engine.stats()["queue_depth"] == 7     # nothing admitted yet
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        assert f.result().shape == (len(p) + MAX_NEW,)
    s = engine.stats()
    assert s["admitted"] == 7
    assert s["retired"] == 7
    assert s["peak_occupancy"] <= 2
    assert s["free_slots"] == 2
    assert s["queue_depth"] == 0


def test_interleaved_admission_preserves_inflight_decode(params):
    """Admitting B mid-flight (prefill + slot write between decode steps)
    must not perturb A's in-flight rows, and vice versa."""
    a, b = _prompts([6, 10], seed=3)
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW)
    fa = engine.submit(a)
    engine.step()
    engine.step()                                 # A is mid-decode
    fb = engine.submit(b)
    _run(engine, [fa, fb])
    np.testing.assert_array_equal(fa.result(), _solo(params, a))
    np.testing.assert_array_equal(fb.result(), _solo(params, b))


def test_per_request_failure_delivery(params):
    """A request that cannot fit fails its own future; neighbours in the
    same step complete untouched."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW)
    good1 = engine.submit(_prompts([5], seed=4)[0])
    bad = engine.submit(np.arange(L, dtype=np.int32))   # L + max_new > L
    good2 = engine.submit(_prompts([7], seed=5)[0])
    with pytest.raises(ValueError, match="context_len"):
        bad.result(timeout=5)
    _run(engine, [good1, good2])
    assert good1.result().shape == (5 + MAX_NEW,)
    assert good2.result().shape == (7 + MAX_NEW,)
    assert engine.stats()["failed"] == 0          # rejected pre-queue
    assert engine.stats()["retired"] == 2


def test_eos_retires_slot_immediately(params):
    """EOS retirement: with eos_id set to the token the model actually
    emits first, the sequence retires after one generated token and its
    slot frees for the next request."""
    prompt = _prompts([6], seed=6)[0]
    probe = ServeEngine(CFG, params, num_slots=1, context_len=L,
                        max_new=MAX_NEW)
    f = probe.submit(prompt)
    _run(probe, [f])
    first_tok = int(f.result()[len(prompt)])

    engine = ServeEngine(CFG, params, num_slots=1, context_len=L,
                         max_new=MAX_NEW, eos_id=first_tok)
    f1 = engine.submit(prompt)
    f2 = engine.submit(_prompts([9], seed=7)[0])
    _run(engine, [f1, f2])
    out = f1.result()
    assert out.shape == (len(prompt) + 1,)        # stopped at EOS
    assert out[-1] == first_tok
    s = engine.stats()
    assert s["retired"] == 2 and s["free_slots"] == 1


def test_stop_fails_pending_requests(params):
    engine = ServeEngine(CFG, params, num_slots=1, context_len=L,
                         max_new=MAX_NEW)
    fut = engine.submit(_prompts([5], seed=8)[0])
    engine.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        fut.result(timeout=5)
    with pytest.raises(RuntimeError, match="stopped"):
        engine.submit(_prompts([5], seed=9)[0]).result(timeout=5)


def test_background_loop_serves(params):
    """The daemon decode loop: submit from this thread, replies stream
    back per request through the futures."""
    with ServeEngine(CFG, params, num_slots=2, context_len=L,
                     max_new=MAX_NEW) as engine:
        prompts = _prompts([5, 8, 11], seed=10)
        futs = [engine.submit(p) for p in prompts]
        for p, f in zip(prompts, futs):
            assert f.result(timeout=120).shape == (len(p) + MAX_NEW,)
    assert engine.stats()["retired"] == 3


# -- roofline decode path: fused windows, kernel dispatch, chunked prefill ----

@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-2b"])
@pytest.mark.parametrize("sync_every", [1, 8])
@pytest.mark.parametrize("impl", ["dense", "flash"])
def test_fused_and_flash_match_solo(arch, sync_every, impl):
    """The whole roofline matrix — {dense, flash kernel dispatch} x
    {sync every step, fused 8-step windows} x {attention-only,
    recurrent} — must be token-identical to solo decoding: the fused
    scan body IS the single-step path, and the kernel is an exact
    drop-in for the dense ring attention."""
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 12)]
    engine = ServeEngine(cfg, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, sync_every=sync_every,
                         decode_impl=impl)
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    import jax.numpy as jnp
    for p, f in zip(prompts, futs):
        solo = np.asarray(serve_lib.generate(
            cfg, params, jnp.asarray(p[None]), max_new=MAX_NEW,
            context_len=L, attn_impl=impl))[0]
        np.testing.assert_array_equal(f.result(), solo)


def test_chunked_prefill_matches_solo(params):
    """Chunked admission (prefill_chunk=4): prompts longer than one chunk
    stream through ``prefill_extend`` between decode steps — including a
    length that is an exact multiple of the chunk and one short enough to
    stay monolithic — and every sequence still equals solo decoding."""
    engine = ServeEngine(CFG, params, num_slots=3, context_len=L,
                         max_new=MAX_NEW, prefill_chunk=4)
    prompts = _prompts([3, 8, 9, 14, 6], seed=12)
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        np.testing.assert_array_equal(f.result(), _solo(params, p))
    s = engine.stats()
    assert s["admitted"] == 5 and s["retired"] == 5
    assert s["free_slots"] == 3                   # no slot leaked by chunking


def test_fused_windows_batch_host_syncs(params):
    """sync_every=8 must actually batch syncs: at max_new=8 the engine
    should sync once per multi-token window plus once per admission —
    far below one sync per generated token."""
    engine = ServeEngine(CFG, params, num_slots=4, context_len=L,
                         max_new=8, sync_every=8).warmup()
    futs = [engine.submit(p, max_new=8) for p in _prompts([5, 7, 6, 9],
                                                          seed=13)]
    _run(engine, futs)
    s = engine.stats()
    assert s["generated_tokens"] == 32
    assert s["host_syncs"] < s["generated_tokens"] / 2
    assert s["syncs_per_token"] <= 0.3


def test_fused_sampling_is_sync_invariant(params):
    """Temperature/top-k sampling carries the PRNG key as device state
    through the fused windows: the same seed must yield the same tokens
    whether the engine syncs every step or every 8."""
    outs = []
    for sync in (1, 8):
        engine = ServeEngine(CFG, params, num_slots=4, context_len=L,
                             max_new=MAX_NEW, temperature=0.7, top_k=5,
                             seed=42, sync_every=sync)
        futs = [engine.submit(p) for p in _prompts([5, 8, 6], seed=14)]
        _run(engine, futs)
        outs.append([f.result() for f in futs])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


# -- paged KV pool + shared-prefix reuse --------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-2b"])
@pytest.mark.parametrize("sync_every", [1, 8])
def test_paged_engine_matches_solo(arch, sync_every):
    """Paged pool (page_size=8) serves token-identically to solo decoding
    for the attention stack (paged rings + page-table walk) AND the
    recurrent stack (no full-context layer to page: the knobs are
    accepted and the flat per-row layout runs underneath)."""
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 12, 7)]
    engine = ServeEngine(cfg, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, sync_every=sync_every,
                         page_size=8, num_pages=12)
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    import jax.numpy as jnp
    for p, f in zip(prompts, futs):
        solo = np.asarray(serve_lib.generate(
            cfg, params, jnp.asarray(p[None]), max_new=MAX_NEW,
            context_len=L))[0]
        np.testing.assert_array_equal(f.result(), solo)


def test_prefix_cache_reuse_matches_cold_prefill(params):
    """Prompts sharing a cached page-aligned prefix skip that prefix's
    prefill (prefix_tokens_reused > 0, cache hits) and still decode
    bit-identically to solo serving from a cold cache."""
    ps = 4
    rng = np.random.default_rng(22)
    shared = rng.integers(0, CFG.vocab_size, 12).astype(np.int32)
    tails = [rng.integers(0, CFG.vocab_size, n).astype(np.int32)
             for n in (3, 5, 2)]
    prompts = [np.concatenate([shared, t]) for t in tails]
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, page_size=ps, num_pages=16)
    f0 = engine.submit(prompts[0])    # cold: registers the shared pages
    _run(engine, [f0])
    futs = [engine.submit(p) for p in prompts[1:]]
    _run(engine, futs)
    for p, f in zip(prompts, [f0] + futs):
        np.testing.assert_array_equal(f.result(), _solo(params, p))
    s = engine.stats()
    assert s["prefix_cache"]["hits"] >= 2         # both warm prompts hit
    assert s["prefix_tokens_reused"] >= 2 * (12 // ps) * ps


def test_prefix_pages_released_on_retirement(params):
    """Retirement releases the rows' page refs immediately; pages that
    stay resident are exactly the prefix-cache entries' chains, and
    draining the cache makes the pool whole again."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, page_size=4, num_pages=16)
    futs = [engine.submit(p) for p in _prompts([10, 13], seed=23)]
    _run(engine, futs)
    s = engine.stats()
    assert s["free_slots"] == 2                   # all rows retired
    held = {pid for chain in engine._prefix._entries.values()
            for pid in chain}
    assert s["pages_in_use"] == len(held)         # cache is the only holder
    while engine._prefix.evict_one(engine._decref):
        pass
    s = engine.stats()
    assert s["pages_free"] == s["pages_total"]


def test_prefix_cache_evicts_under_pool_pressure(params):
    """A pool too small to keep every retired prompt's prefix cached:
    admission evicts LRU refcount-zero entries instead of deadlocking,
    everything completes, and results stay exact."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, page_size=4, num_pages=8)
    rng = np.random.default_rng(24)
    prompts = [rng.integers(0, CFG.vocab_size, 12).astype(np.int32)
               for _ in range(6)]
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        np.testing.assert_array_equal(f.result(), _solo(params, p))
    s = engine.stats()
    assert s["retired"] == 6
    assert s["prefix_cache"]["evictions"] >= 1


def test_request_exceeding_page_pool_fails_fast(params):
    """A request whose page budget can never be satisfied fails its own
    future at submit time (like the context_len check) instead of
    blocking admission forever."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, page_size=4, num_pages=2)
    fut = engine.submit(np.arange(12, dtype=np.int32))    # needs 4 pages
    with pytest.raises(ValueError, match="pages"):
        fut.result(timeout=5)
    ok = engine.submit(_prompts([3], seed=25)[0])         # 2 pages: fits
    _run(engine, [ok])
    assert ok.result().shape == (3 + MAX_NEW,)


def test_paged_chunked_prefill_matches_solo(params):
    """Chunked admission against a paged pool: the B=1 chunk state lands
    through the copy-on-write scatter (start_page skips shared pages)
    and every sequence equals solo decoding."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, prefill_chunk=4,
                         page_size=8, num_pages=9)
    prompts = _prompts([3, 9, 14, 6], seed=25)
    futs = [engine.submit(p) for p in prompts]
    _run(engine, futs)
    for p, f in zip(prompts, futs):
        np.testing.assert_array_equal(f.result(), _solo(params, p))
    s = engine.stats()
    assert s["free_slots"] == 2                   # no slot or page leaked
    assert s["pages_in_use"] == len(
        {pid for chain in engine._prefix._entries.values() for pid in chain})


def test_warmup_precompiles_paged_and_chunk_executables(params):
    """warmup() compiles the paged fused-window ladder and the
    chunk-shaped prefill_extend without touching live state; serving
    afterwards is exact."""
    engine = ServeEngine(CFG, params, num_slots=2, context_len=L,
                         max_new=MAX_NEW, prefill_chunk=4,
                         page_size=8, num_pages=9).warmup()
    futs = [engine.submit(p) for p in _prompts([9, 5], seed=26)]
    _run(engine, futs)
    for p, f in zip(_prompts([9, 5], seed=26), futs):
        np.testing.assert_array_equal(f.result(), _solo(params, p))
