import os

# Tests run on the single real CPU device; ONLY the dry-run tests use
# placeholder devices, and those shard over whatever exists (they never
# assume 512). Keep jax quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest


@pytest.fixture(autouse=True)
def _reset_inproc_registry():
    """Each test gets a clean in-process courier registry."""
    from repro.core.courier import inprocess
    inprocess.reset()
    yield
    inprocess.reset()
