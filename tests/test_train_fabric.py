"""Elastic actor-learner training fabric: typed replay stalls, gradient
wire compression, and the supervisor's survival story (kill the chief ->
bounded step loss; kill an actor -> zero; elastic grow/shrink).

Fast end-to-end tests drive a real in-process fleet — Registry + replay +
actors + learners on a ThreadWorkerSpawner over the inproc courier — on a
toy regression task; the full chaos arms run in benchmarks/train_bench.py.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import courier
from repro.core.discovery import Registry
from repro.core.fault import RestartPolicy, hedged_map
from repro.data.replay import (ReplayServer, TableConfig, WriterStalled,
                               is_writer_stalled)
from repro.train import fabric, grad_compression
from repro.train.optimizer import OptimizerConfig


# -- typed replay stalls ------------------------------------------------------

def _stall_table():
    # SPI budget of ~1 sample per insert with tiny tolerance: with no
    # sampler draining, inserts run ahead fast and hit the limiter.
    return TableConfig(name="t", max_size=100, min_size_to_sample=1,
                       samples_per_insert=1.0, spi_tolerance=1.0)


def test_insert_raises_writer_stalled_past_deadline():
    server = ReplayServer([_stall_table()])
    while server.insert("t", {"x": 1}, 1.0, 0.05, False):
        pass                                   # exhaust the SPI budget
    with pytest.raises(WriterStalled) as ei:
        server.insert("t", {"x": 1}, 1.0, 0.05, True)
    assert ei.value.table == "t"
    assert is_writer_stalled(ei.value)
    # The bool-returning path is unchanged: same stall, no raise.
    assert server.insert("t", {"x": 1}, 1.0, 0.05) is False


def test_writer_stalled_unwraps_across_inproc_courier():
    server = ReplayServer([_stall_table()])
    courier.inprocess.register("replay-x", server)
    client = courier.client_for("inproc://replay-x")
    while client.insert("t", {"x": 1}, 1.0, 0.05, False):
        pass
    with pytest.raises(Exception) as ei:
        client.insert("t", {"x": 1}, 1.0, 0.05, True)
    assert is_writer_stalled(ei.value)         # typed through the transport
    assert not is_writer_stalled(ValueError("nope"))


# -- gradient wire compression ------------------------------------------------

def _tree(key=0):
    rng = np.random.default_rng(key)
    return {"w": rng.normal(size=(8, 4)).astype(np.float32),
            "b": rng.normal(size=(4,)).astype(np.float32)}


def test_dense_payload_roundtrips_exactly():
    g = _tree()
    payload, err = grad_compression.compress_tree(g, None, method="dense")
    out = grad_compression.decompress_tree(payload)
    assert err is None
    for k in g:
        np.testing.assert_array_equal(out[k], g[k])


def test_int8_roundtrip_error_is_bounded_by_scale():
    g = _tree()
    payload, err = grad_compression.compress_tree(g, None, method="int8_ef")
    out = grad_compression.decompress_tree(payload)
    for k in g:
        scale = float(np.max(np.abs(g[k]))) / 127.0
        assert np.max(np.abs(out[k] - g[k])) <= scale * 0.5 + 1e-7
        # The residual is exactly what the wire dropped.
        np.testing.assert_allclose(err[k], g[k] - out[k], atol=1e-6)


def test_error_feedback_cancels_quantization_bias():
    """Feeding the residual back makes the *running sum* of dequantized
    gradients track the true sum — the bias does not accumulate."""
    g = _tree()
    err = None
    sent = jax.tree.map(np.zeros_like, g)
    n = 50
    for _ in range(n):
        payload, err = grad_compression.compress_tree(g, err, method="int8_ef")
        out = grad_compression.decompress_tree(payload)
        sent = jax.tree.map(np.add, sent, out)
    for k in g:
        scale = float(np.max(np.abs(g[k]))) / 127.0
        # Without EF the worst-case drift is ~n * scale/2; with EF the
        # total error stays bounded by one quantization step.
        assert np.max(np.abs(sent[k] - n * g[k])) <= 2 * scale


def test_select_strategy_by_gradient_size():
    small = {"w": np.zeros((4, 4), np.float32)}
    assert grad_compression.select_strategy(small, threshold_bytes=1024) \
        == "dense"
    assert grad_compression.select_strategy(small, threshold_bytes=64) \
        == "int8_ef"
    assert grad_compression.grad_bytes(small) == 64


def test_compress_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown"):
        grad_compression.compress_tree(_tree(), None, method="fp4")


# -- quorum aggregation over survivors ----------------------------------------

def test_hedged_map_return_exceptions_degrades_not_fails():
    import concurrent.futures as cf

    def ok():
        return 1

    def boom():
        raise RuntimeError("peer died")

    with cf.ThreadPoolExecutor(3) as pool:
        results = hedged_map(
            [lambda: pool.submit(ok), lambda: pool.submit(boom),
             lambda: pool.submit(ok)],
            timeout_s=5.0, quorum=3, return_exceptions=True)
    assert results[0] == 1 and results[2] == 1
    assert isinstance(results[1], RuntimeError)


# -- end-to-end fleet ---------------------------------------------------------

def _target(x):
    return np.sin(x[:, 0]) + 0.5 * x[:, 1]


def _rollout(params, rng):
    x = rng.normal(size=(8, 4)).astype(np.float32)
    return {"x": x, "y": _target(x).astype(np.float32)}


class ToyTask:
    optimizer = OptimizerConfig(lr=0.03, warmup_steps=0,
                                total_steps=1_000_000, weight_decay=0.0,
                                clip_norm=None)

    def init_params(self, key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (4, 16)) * 0.5,
                "b1": jnp.zeros((16,)),
                "w2": jax.random.normal(k2, (16, 1)) * 0.5,
                "b2": jnp.zeros((1,))}

    def grad_fn(self, params, batch):
        def loss(p):
            h = jnp.tanh(batch["x"] @ p["w1"] + p["b1"])
            pred = (h @ p["w2"] + p["b2"])[:, 0]
            return jnp.mean((pred - batch["y"]) ** 2)
        return jax.value_and_grad(loss)(params)

    def collate(self, items):
        return {"x": np.concatenate([it["x"] for it in items]),
                "y": np.concatenate([it["y"] for it in items])}


class _Fleet:
    def __init__(self, store_dir, *, learners=1, actors=1, total_steps=12,
                 publish_every=4):
        self.store_dir = str(store_dir)
        self.registry = Registry(ttl_s=1.0)
        self.spawner = fabric.ThreadWorkerSpawner()
        self.cfg = fabric.FabricConfig(
            total_steps=total_steps, batch_size=4,
            publish_every=publish_every, peer_timeout_s=5.0,
            heartbeat_s=0.05, insert_timeout_s=0.5, sample_timeout_s=0.5)
        task = ToyTask()
        table = TableConfig(name="batches", max_size=500,
                            min_size_to_sample=8)
        resolver = fabric.registry_resolver(self.registry, "replay")
        cfg, registry, spawner = self.cfg, self.registry, self.spawner
        store = self.store_dir

        def spawn_fn(name):
            role, idx = name.rsplit("-", 1)
            if role == "replay":
                spawner.spawn(name, lambda n, ep: fabric.ReplayService(
                    [table], registry, name=n, endpoint=ep,
                    heartbeat_s=cfg.heartbeat_s))
            elif role == "learner":
                batch_fn = fabric.replay_batch_fn(
                    resolver, "batches", task.collate, cfg.batch_size,
                    cfg.sample_timeout_s)
                spawner.spawn(name, lambda n, ep, i=int(idx):
                              fabric.LearnerWorker(
                                  task, batch_fn, store, registry, cfg,
                                  name=n, chief=(i == 0), endpoint=ep))
            elif role == "actor":
                spawner.spawn(name, lambda n, ep, i=int(idx):
                              fabric.ActorWorker(
                                  task, _rollout, resolver, "batches",
                                  store, registry, cfg, name=n,
                                  endpoint=ep, seed=100 + i))
            else:
                raise ValueError(name)

        self.sup = fabric.TrainSupervisor(
            self.registry, spawn_fn,
            expected={"replay": 1, "actor": actors, "learner": learners},
            policy=RestartPolicy(max_restarts=8, backoff_s=0.02),
            spawn_grace_s=10.0, total_steps=total_steps)

    def lookup(self, name):
        for r in self.registry.lookup()["replicas"]:
            if r["name"] == name:
                return r["load"]
        return None

    def chief(self):
        for r in self.registry.lookup()["replicas"]:
            load = r["load"]
            if load.get("role") == "learner" and load.get("chief"):
                return load
        return None

    def drive(self, events=(), timeout_s=90.0):
        """Poll to completion, firing (trigger_step, fn) events once when
        the chief first reports that step. Returns the final chief load."""
        t0 = time.monotonic()
        fired = [False] * len(events)
        last = None
        while time.monotonic() - t0 < timeout_s:
            self.sup.poll()
            load = self.chief()
            if load is not None:
                last = load
                for i, (trig, fn) in enumerate(events):
                    if not fired[i] and load["step"] >= trig:
                        fired[i] = True
                        fn()
            if self.sup.done:
                # The supervisor flips done on step >= total, which can
                # precede the chief's own done=True beat — wait for it so
                # callers see the final load report.
                deadline = time.monotonic() + 5.0
                while time.monotonic() < deadline:
                    load = self.chief()
                    if load is not None and load.get("done"):
                        return load
                    time.sleep(0.02)
                return last
            time.sleep(0.02)
        raise AssertionError(
            f"fleet did not finish in {timeout_s}s: chief={last}, "
            f"stats={self.sup.stats()}")

    def versions(self):
        from repro.ckpt.checkpoint import ModelStore
        return ModelStore(self.store_dir).versions()

    def close(self):
        self.spawner.stop_all()


@pytest.fixture
def fleet_factory(tmp_path):
    fleets = []

    def make(**kw):
        f = _Fleet(tmp_path / f"store{len(fleets)}", **kw)
        fleets.append(f)
        return f

    yield make
    for f in fleets:
        f.close()


def test_fleet_trains_to_done_and_publishes(fleet_factory):
    fleet = fleet_factory(total_steps=8, publish_every=4)
    load = fleet.drive()
    assert load["step"] >= 8 and load["done"]
    assert load["start_step"] == 0              # never restored
    assert fleet.versions() == [4, 8]           # every publish boundary
    assert fleet.sup.stats()["restarts"] == {}  # no faults, no respawns


def test_kill_chief_restores_with_bounded_step_loss(fleet_factory):
    fleet = fleet_factory(learners=2, total_steps=12, publish_every=4)
    kill_at = {}

    def kill_chief():
        kill_at["step"] = fleet.chief()["step"]
        fabric.RegistryTarget(fleet.registry, "learner-0").kill()

    # Fire between publish boundaries so the regression is visible.
    load = fleet.drive([(6, kill_chief)])
    assert load["step"] >= 12 and load["done"]
    assert fleet.sup.stats()["restarts"].get("learner-0", 0) >= 1
    # The respawned chief resumed from the last *published* version:
    assert load["start_step"] > 0
    assert kill_at["step"] - load["start_step"] <= 4   # <= publish_every


def test_kill_actor_costs_zero_steps(fleet_factory):
    fleet = fleet_factory(actors=2, total_steps=10, publish_every=5)
    load = fleet.drive(
        [(3, lambda: fabric.RegistryTarget(fleet.registry,
                                           "actor-0").kill())])
    assert load["step"] >= 10 and load["done"]
    # Actors are stateless: the learner never restarts or restores.
    assert load["start_step"] == 0
    restarts = fleet.sup.stats()["restarts"]
    assert not any(k.startswith("learner") for k in restarts)
    # The small fleet can finish before the actor's TTL eviction lands;
    # keep polling so the test asserts the detect->respawn cycle.
    deadline = time.monotonic() + 10.0
    while (not fleet.sup.stats()["restarts"].get("actor-0")
           and time.monotonic() < deadline):
        fleet.sup.poll()
        time.sleep(0.02)
    assert fleet.sup.stats()["restarts"].get("actor-0", 0) >= 1


def test_elastic_grow_joins_from_published_version(fleet_factory):
    fleet = fleet_factory(learners=1, total_steps=14, publish_every=4)
    fleet.drive([(5, lambda: fleet.sup.scale("learner", 2))])
    grown = fleet.lookup("learner-1")
    assert grown is not None and not grown["chief"]
    # The grown learner restored the latest published version in its ctor
    # (its start_step is a publish boundary, not 0).
    assert grown["start_step"] > 0
    assert grown["start_step"] % 4 == 0


def test_elastic_shrink_retires_gracefully(fleet_factory):
    fleet = fleet_factory(learners=2, total_steps=12, publish_every=4)
    load = fleet.drive([(4, lambda: fleet.sup.scale("learner", 1))])
    assert load["step"] >= 12 and load["done"]
    assert fleet.lookup("learner-1") is None    # deregistered, not dead
    stats = fleet.sup.stats()
    assert stats["expected"]["learner"] == 1
    assert not stats["restarts"]                # retire is not a fault
