"""Shared-memory ring transport: ring/chan mechanics, dual-endpoint
selection, spill paths, and — critically — the failure modes: killed server
mid-flight, closed transports, and stale rendezvous state falling back to
gRPC instead of deadlocking."""

import multiprocessing as mp
import json
import os
import time
import weakref

import numpy as np
import pytest

from repro.core import courier
from repro.core.courier import serialization as ser
from repro.core.courier import shm
from repro.core.courier.server import CourierServer
from repro.core.courier.transport import (GrpcTransport, ShmTransport,
                                          make_transport)


class Service:
    def ping(self):
        return 1

    def echo(self, x):
        return x

    def add(self, a, b=0):
        return a + b

    def boom(self):
        raise ValueError("intentional")

    def sleepy(self, s):
        time.sleep(s)
        return "done"


@pytest.fixture
def shm_server():
    name = f"t{os.getpid():x}{time.monotonic_ns() & 0xffffff:x}"
    srv = CourierServer(Service(), shm_name=name)
    srv.start()
    yield srv, name
    srv.stop()


def _shm_client(srv, name):
    cli = courier.client_for(f"shm://{name}+{srv.endpoint}")
    assert isinstance(cli.transport, ShmTransport)
    return cli


# ---- ring mechanics ----------------------------------------------------------

def test_ring_records_roundtrip_across_wrap():
    ring = shm.Ring.create(f"ringwrap{os.getpid():x}", capacity=4096)
    try:
        # Enough traffic to wrap several times, with sizes that land
        # records on awkward tail boundaries.
        for i in range(200):
            body = bytes([i & 0xFF]) * (17 + 119 * (i % 13))
            ring.write(1, i, [body])
            rec = ring.read(timeout=5)
            assert rec == (1, i, body)
    finally:
        ring.release(unlink=True)


def test_ring_blocks_then_recycles_when_full():
    ring = shm.Ring.create(f"ringfull{os.getpid():x}", capacity=1024)
    try:
        ring.write(1, 1, [b"x" * 700])
        with pytest.raises(TimeoutError):
            ring.write(1, 2, [b"y" * 700], timeout=0.05)
        assert ring.read(timeout=1)[2] == b"x" * 700
        ring.write(1, 3, [b"y" * 700], timeout=1)  # space recycled
        assert ring.read(timeout=1)[1] == 3
    finally:
        ring.release(unlink=True)


def test_ring_reader_sees_writer_close():
    ring = shm.Ring.create(f"ringclose{os.getpid():x}", capacity=1024)
    try:
        ring.write(1, 1, [b"last"])
        ring.close_write()
        assert ring.read(timeout=1)[2] == b"last"  # drains pending data
        with pytest.raises(shm.RingClosed):
            ring.read(timeout=1)
    finally:
        ring.release(unlink=True)


# ---- transport over a live server -------------------------------------------

def test_shm_roundtrip_inline_and_bulk(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        assert cli.ping() == 1
        small = np.arange(512, dtype=np.int32)          # inline record
        np.testing.assert_array_equal(cli.echo(small), small)
        big = np.arange(1 << 20, dtype=np.uint8)        # bulk-ring record
        np.testing.assert_array_equal(cli.echo(big), big)


def test_shm_remote_error_and_futures(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        with pytest.raises(courier.RemoteError, match="intentional"):
            cli.boom()
        futs = [cli.futures.add(i, b=10) for i in range(16)]
        assert [f.result(10) for f in futs] == [10 + i for i in range(16)]


def test_shm_batch_call_order_and_isolation(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        calls = [("add", (i,), {}) for i in range(8)]
        assert cli.batch_call(calls) == list(range(8))
        mixed = [("add", (1,), {}), ("boom", (), {}), ("add", (2,), {})]
        out = cli.batch_call(mixed, return_exceptions=True)
        assert out[0] == 1 and out[2] == 2
        assert isinstance(out[1], courier.RemoteError)


def test_shm_refuses_run_and_private(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        with pytest.raises(courier.RemoteError):
            cli.run()


# ---- zero-copy slot pool: leases, overlap, unlink ---------------------------

BIG = 256 * 1024  # comfortably over SPILL_THRESHOLD


def test_zero_copy_reply_aliases_slot_and_is_read_only(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        big = np.arange(BIG, dtype=np.uint8)
        out = cli.echo(big)
        np.testing.assert_array_equal(out, big)
        assert not out.flags.writeable  # aliases the slot: read-only
        assert isinstance(ser.owner_of(out), shm.SlotLease)
        # materialize detaches: owned memory, no lease attached
        copied = courier.materialize(out)
        assert ser.owner_of(copied) is None
        np.testing.assert_array_equal(copied, big)
        lease_ref = weakref.ref(ser.owner_of(out))
        del out
        # Refcount-prompt free: the lease dies with the object graph
        # (no gc cycle), returning the slot to the pool.
        assert lease_ref() is None
        pools = cli.transport._conn._in._pools_attached
        assert pools and all(p.all_free for p in pools.values())


def test_pipelined_large_messages_overlap_not_serialize(shm_server):
    """A held reply lease pins its slot; further large calls must use
    other slots of the pool instead of deadlocking on the first."""
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        first = cli.echo(np.full(BIG, 1, np.uint8))  # lease held
        second = cli.echo(np.full(BIG, 2, np.uint8))
        third = cli.echo(np.full(BIG, 3, np.uint8))
        assert first[0] == 1 and second[0] == 2 and third[0] == 3
        # and concurrently, via futures (in-flight > 1 at once)
        futs = [cli.futures.echo(np.full(BIG, 10 + i, np.uint8))
                for i in range(shm.SLOT_COUNT + 2)]  # > pool size: expands
        outs = [f.result(30) for f in futs]
        assert [int(o[0]) for o in outs] == [10 + i for i in range(
            shm.SLOT_COUNT + 2)]


def test_slot_pool_reuses_slots_without_growth(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        conn_id = cli.transport._conn._conn_id
        big = np.zeros(BIG, np.uint8)
        for _ in range(3 * shm.SLOT_COUNT):  # results dropped each loop
            cli.echo(big)
        if os.path.isdir("/dev/shm"):
            segs = [f for f in os.listdir("/dev/shm")
                    if f.startswith(conn_id)]
            # two rings + at most one pool per direction
            assert len(segs) <= 4, segs


def test_lease_outlives_transport_close_no_segfault_no_leak(shm_server):
    """A decoded view kept past close() must stay readable (the mapping
    outlives the unlink), while every segment name is gone from /dev/shm
    — and the final lease release must drop the mapping."""
    srv, name = shm_server
    cli = _shm_client(srv, name)
    big = np.arange(BIG, dtype=np.uint8)
    kept = cli.echo(big)
    lease_ref = weakref.ref(ser.owner_of(kept))
    conn_id = cli.transport._conn._conn_id
    cli.close()
    if os.path.isdir("/dev/shm"):
        time.sleep(0.1)
        leftovers = [f for f in os.listdir("/dev/shm")
                     if f.startswith(conn_id)]
        assert not leftovers, leftovers  # unlinked eagerly on close
    np.testing.assert_array_equal(kept, big)  # mapping still alive
    del kept
    assert lease_ref() is None  # final release: mapping dropped too


def test_explicit_lease_release_frees_slot(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        out = cli.echo(np.zeros(BIG, np.uint8))
        lease = ser.owner_of(out)
        assert not lease.released
        lease.release()  # consumer opts out early (data may be reused)
        assert lease.released
        lease.release()  # idempotent
        pools = cli.transport._conn._in._pools_attached
        assert all(p.all_free for p in pools.values())


def test_copy_mode_roundtrip_and_detached_results(shm_server):
    """zero_copy=False (the bench A/B baseline arm) must behave like
    PR-2: results are copies, no lease attached."""
    srv, name = shm_server
    t = ShmTransport(name, zero_copy=False)
    try:
        big = np.arange(BIG, dtype=np.uint8)
        out = t.call("echo", (big,), {})
        np.testing.assert_array_equal(out, big)
        assert ser.owner_of(out) is None
    finally:
        t.close()


def test_slot_pool_growth_across_message_sizes(shm_server):
    srv, name = shm_server
    with _shm_client(srv, name) as cli:
        for size in (128 * 1024, 1 << 20, 4 << 20, 256 * 1024):
            big = np.full(size, size % 251, np.uint8)
            np.testing.assert_array_equal(cli.echo(big), big)


# ---- endpoint selection / fallback ------------------------------------------

def test_dual_endpoint_prefers_shm_then_falls_back(shm_server, monkeypatch):
    srv, name = shm_server
    dual = f"shm://{name}+{srv.endpoint}"
    t = make_transport(dual)
    assert isinstance(t, ShmTransport)
    t.close()
    # Absent listener + grpc fallback: short grace, then gRPC.
    monkeypatch.setattr(shm, "CONNECT_WAIT_S", 0.2)
    t2 = make_transport(f"shm://absent-{name}+{srv.endpoint}")
    assert isinstance(t2, GrpcTransport)
    t2.close()


def test_stale_rendezvous_falls_back_to_grpc_not_deadlock(shm_server):
    """A crashed server leaves its rendezvous dir behind; a client must
    detect the dead pid immediately and take gRPC, not hang on dead
    shared memory."""
    srv, name = shm_server
    stale = f"stale{os.getpid():x}"
    d = shm.rendezvous_dir(stale)
    os.makedirs(d, exist_ok=True)
    # A pid that is long gone: fork a child that exits immediately.
    child = mp.get_context("fork").Process(target=lambda: None)
    child.start()
    child.join()
    with open(os.path.join(d, "listener.json"), "w") as f:
        json.dump({"host": __import__("socket").gethostname(),
                   "pid": child.pid, "version": 1}, f)
    try:
        assert shm.probe(stale) == "stale"
        t0 = time.monotonic()
        t = make_transport(f"shm://{stale}+{srv.endpoint}")
        elapsed = time.monotonic() - t0
        assert isinstance(t, GrpcTransport)
        assert elapsed < 2.0, f"stale fallback took {elapsed:.1f}s"
        assert t.call("ping", (), {}) == 1  # the fallback actually works
        t.close()
    finally:
        shm.cleanup(stale)


def test_legacy_wire_format_skips_shm(shm_server):
    """An explicit legacy-format client must not land on the (framed-only)
    shm transport even when the dual endpoint advertises it."""
    from repro.core.courier.client import CourierClient
    srv, name = shm_server
    with CourierClient(f"shm://{name}+{srv.endpoint}",
                       wire_format="legacy") as cli:
        assert isinstance(cli.transport, GrpcTransport)
        assert cli.ping() == 1


def test_call_timeout_unregisters_pending(shm_server):
    srv, name = shm_server
    t = ShmTransport(name, timeout=0.3)
    try:
        with pytest.raises(courier.RemoteError, match="timed out"):
            t.call("sleepy", (5,), {})
        assert not t._pending  # timed-out request must not leak
    finally:
        t.close()


def test_shm_only_endpoint_with_no_listener_raises(monkeypatch):
    monkeypatch.setattr(shm, "CONNECT_WAIT_S", 0.2)
    with pytest.raises(courier.RemoteError, match="did not come up"):
        make_transport(f"shm://never-{os.getpid():x}")


# ---- failure paths -----------------------------------------------------------

def _victim_server(name, ready):
    srv = CourierServer(Service(), shm_name=name)
    srv.start()
    ready.put(srv.endpoint)
    time.sleep(60)


def test_server_killed_mid_call_future_fails_pending():
    name = f"kill{os.getpid():x}"
    ctx = mp.get_context("fork")
    q = ctx.Queue()
    proc = ctx.Process(target=_victim_server, args=(name, q), daemon=True)
    proc.start()
    grpc_ep = q.get(timeout=20)
    t = make_transport(f"shm://{name}+{grpc_ep}")
    assert isinstance(t, ShmTransport)
    try:
        assert t.call("ping", (), {}) == 1
        proc.terminate()
        proc.join(timeout=10)
        # Depending on how fast the reader notices, the failure surfaces
        # either at submit time (transport marked broken) or on the future.
        with pytest.raises(courier.RemoteError):
            t.call_future("ping", (), {}).result(timeout=20)
    finally:
        t.close()


def test_server_stop_fails_pending_not_deadlocks(shm_server):
    srv, name = shm_server
    t = make_transport(f"shm://{name}+{srv.endpoint}")
    assert isinstance(t, ShmTransport)
    try:
        assert t.call("ping", (), {}) == 1
        srv.stop()
        # The connection thread drains in-flight work before tearing
        # down, so the first post-stop call may still succeed; within a
        # couple of poll cycles every call must fail cleanly — and never
        # hang.
        for _ in range(100):
            try:
                t.call_future("ping", (), {}).result(timeout=20)
            except courier.RemoteError:
                break
            time.sleep(0.05)
        else:
            pytest.fail("calls kept succeeding after server stop")
    finally:
        t.close()


def test_batch_call_on_closed_transport_raises(shm_server):
    srv, name = shm_server
    t = make_transport(f"shm://{name}+{srv.endpoint}")
    t.close()
    with pytest.raises(RuntimeError, match="closed"):
        t.batch_call([("ping", (), {})])
    t.close()  # double-close is a no-op


def test_client_rings_unlinked_on_close(shm_server):
    srv, name = shm_server
    t = make_transport(f"shm://{name}+{srv.endpoint}")
    assert isinstance(t, ShmTransport)
    conn_id = t._conn._conn_id
    assert t.call("ping", (), {}) == 1
    t.close()
    if os.path.isdir("/dev/shm"):
        time.sleep(0.1)
        leftovers = [f for f in os.listdir("/dev/shm")
                     if f.startswith(conn_id)]
        assert not leftovers, leftovers


def test_mesh_worker_serves_dual_endpoint_under_process_launcher():
    """Regression: MeshExecutable must parse the process launcher's
    '+'-joined shm+grpc endpoints like _CourierExecutable does."""
    import tempfile

    class Learner:
        def __init__(self, mesh=None):
            self._mesh = mesh

        def axes(self):
            return tuple(self._mesh.axis_names)

    class Driver:
        def __init__(self, learner, out_path):
            self._learner = learner
            self._out = out_path

        def run(self):
            axes = self._learner.axes()
            kind = type(self._learner.transport).__name__
            with open(self._out, "w") as f:
                f.write(f"{','.join(axes)} {kind}")
            from repro import core as lp
            lp.stop_program()

    from repro import core as lp
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "out")
        p = lp.Program("meshshm")
        with p.group("learner"):
            h = p.add_node(lp.MeshWorkerNode(Learner))
        with p.group("driver"):
            p.add_node(lp.CourierNode(Driver, h, out))
        launcher = lp.ProcessLauncher()
        launcher.launch(p, resources={
            "learner": {"mesh": (1,), "axes": ("data",)}})
        assert launcher.wait(timeout=120)
        axes, kind = open(out).read().split()
        assert axes == "data"
        assert kind == "ShmTransport"


# ---- grpc satellite: bounded connect + clear errors -------------------------

def test_grpc_never_up_endpoint_raises_remote_error_with_deadline():
    t = GrpcTransport("grpc://127.0.0.1:1", timeout=0.5)
    t0 = time.monotonic()
    with pytest.raises(courier.RemoteError, match="127.0.0.1:1"):
        t.call("ping", (), {})
    assert time.monotonic() - t0 < 10.0
    t.close()


def test_grpc_server_killed_surfaces_remote_error():
    name = f"gk{os.getpid():x}"
    ctx = mp.get_context("fork")
    q = ctx.Queue()
    proc = ctx.Process(target=_victim_server, args=(name, q), daemon=True)
    proc.start()
    grpc_ep = q.get(timeout=20)
    t = GrpcTransport(grpc_ep, timeout=5.0)
    try:
        assert t.call("ping", (), {}) == 1
        proc.terminate()
        proc.join(timeout=10)
        with pytest.raises(courier.RemoteError, match=grpc_ep):
            t.call("ping", (), {})
    finally:
        t.close()
        shm.cleanup(name)
