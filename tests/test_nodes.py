"""Service types: CacherNode, ColocationNode, ReverbNode, MeshWorkerNode,
hedged fan-out."""

import threading
import time

import pytest

from repro import core as lp
from repro.data.replay import TableConfig


class Counter:
    def __init__(self):
        self._n = 0
        self._lock = threading.Lock()

    def value(self):
        with self._lock:
            self._n += 1
            return self._n


class CacheProbe:
    """Constructor args are SERIALIZED (deferred construction), so results
    are asserted inside the service — a failure crashes the node, which the
    test launcher reports as fatal."""

    def __init__(self, cached):
        self._cached = cached

    def run(self):
        vals = [self._cached.value() for _ in range(20)]
        # One origin hit; 19 served from cache.
        assert vals == [1] * 20, vals
        stats = self._cached.cache_stats()
        assert stats["misses"] == 1 and stats["hits"] >= 19, stats
        lp.stop_program()


def test_cacher_collapses_requests():
    p = lp.Program("c")
    origin = p.add_node(lp.CourierNode(Counter))
    cached = p.add_node(lp.CacherNode(origin, timeout_s=30.0))
    p.add_node(lp.CourierNode(CacheProbe, cached))
    lp.launch_and_wait(p, timeout_s=20)


def test_cacher_expires():
    from repro.core.nodes.cacher import Cacher
    origin = Counter()
    c = Cacher(origin, timeout_s=0.05)
    assert c.value() == 1
    assert c.value() == 1
    time.sleep(0.08)
    assert c.value() == 2
    stats = c.cache_stats()
    assert stats["misses"] == 2 and stats["hits"] == 1


def test_colocation_runs_wrapped_nodes_inproc():
    done = []

    class A:
        def ping(self):
            return "a"

    class B:
        def __init__(self, a):
            self._a = a

        def run(self):
            done.append(self._a.ping())
            lp.stop_program()

    p = lp.Program("co")
    na = lp.CourierNode(A)
    ha = na.create_handle()
    nb = lp.CourierNode(B, ha)
    p.add_node(lp.ColocationNode(na, nb))
    lp.launch_and_wait(p, timeout_s=20)
    assert done == ["a"]


class ReplayWriter:
    def __init__(self, replay):
        self._replay = replay

    def run(self):
        for i in range(10):
            assert self._replay.insert("t", {"step": i})
        lp.stop_program()


def test_reverb_node_serves_replay():
    p = lp.Program("rb")
    replay = p.add_node(lp.ReverbNode([TableConfig("t", max_size=100)]))
    p.add_node(lp.CourierNode(ReplayWriter, replay))
    launcher = lp.launch_and_wait(p, timeout_s=20)
    del launcher


def test_mesh_worker_node_gets_mesh():
    got = {}

    class Learner:
        def __init__(self, mesh=None):
            got["mesh"] = mesh

        def run(self):
            lp.stop_program()

    p = lp.Program("mesh")
    with p.group("learner"):
        p.add_node(lp.MeshWorkerNode(Learner))
    lp.launch_and_wait(
        p, resources={"learner": {"mesh": (1, 1), "axes": ("data", "model")}},
        timeout_s=30)
    mesh = got["mesh"]
    assert mesh is not None and mesh.axis_names == ("data", "model")


def test_mesh_worker_rejects_oversized_mesh():
    class Learner:
        def __init__(self, mesh=None):
            pass

    p = lp.Program("mesh2")
    with p.group("learner"):
        p.add_node(lp.MeshWorkerNode(Learner))
    with pytest.raises(lp.ProgramTestError):
        lp.launch_and_wait(
            p, resources={"learner": {"mesh": (4096,), "axes": ("data",)}},
            timeout_s=30)


def test_hedged_map_quorum_and_hedging():
    from concurrent import futures as cf
    pool = cf.ThreadPoolExecutor(8)

    def slow(i):
        def call():
            def work():
                time.sleep(2.0 if i == 0 else 0.05)
                return i
            return pool.submit(work)
        return call

    t0 = time.monotonic()
    res = lp.hedged_map([slow(i) for i in range(4)], quorum=3)
    assert time.monotonic() - t0 < 1.5
    assert res.count(None) >= 1  # the straggler was abandoned
    assert set(x for x in res if x is not None) <= {0, 1, 2, 3}
