"""Training/serving behaviour: loss decreases, microbatch equivalence,
decode==prefill continuation, data pipeline, generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, Prefetcher, make_source
from repro.models import transformer
from repro.serve import decode as serve_lib
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainConfig, make_train_state,
                                    make_train_step, split_batch)


def test_training_learns_synthetic():
    cfg = configs.get_reduced("qwen2-1.5b")
    tc = TrainConfig(optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                               total_steps=60),
                     num_microbatches=2)
    step = jax.jit(make_train_step(cfg, tc))
    params, opt = make_train_state(cfg, jax.random.key(0))
    src = iter(make_source(DataConfig(seq_len=32, batch_size=8,
                                      vocab_size=cfg.vocab_size)))
    losses = []
    for _ in range(40):
        params, opt, m = step(params, opt, jax.tree.map(jnp.asarray, next(src)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatching_matches_full_batch():
    cfg = configs.get_reduced("qwen3-8b")
    params, opt = make_train_state(cfg, jax.random.key(1))
    src = iter(make_source(DataConfig(seq_len=16, batch_size=8,
                                      vocab_size=cfg.vocab_size)))
    batch = jax.tree.map(jnp.asarray, next(src))

    outs = []
    for nm in (1, 4):
        tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                                   total_steps=10),
                         num_microbatches=nm)
        p2, _, m = jax.jit(make_train_step(cfg, tc))(params, opt, batch)
        outs.append(p2)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_split_batch_shapes():
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32)}
    out = split_batch(batch, 4)
    assert out["tokens"].shape == (4, 2, 16)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x7b",
                                  "recurrentgemma-2b", "falcon-mamba-7b"])
def test_decode_matches_prefill_continuation(arch):
    """Greedy decode after prefill(S) == argmax of prefill(S+1) logits."""
    cfg = configs.get_reduced(arch)
    key = jax.random.key(2)
    params = transformer.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 24), 0, cfg.vocab_size)
    _, state = transformer.prefill(cfg, params, tokens=toks, context_len=48)
    new_tok = jnp.full((2, 1), 7, jnp.int32)
    logits, _ = transformer.decode_step(cfg, params, state, new_tok,
                                        jnp.int32(24))
    ext = jnp.concatenate([toks, new_tok], axis=1)
    logits_ext, _ = transformer.prefill(cfg, params, tokens=ext,
                                        context_len=48)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits[:, -1], -1)),
        np.asarray(jnp.argmax(logits_ext[:, -1], -1)))


def test_generate_produces_tokens():
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.key(3))
    prompt = jax.random.randint(jax.random.key(4), (2, 8), 0, cfg.vocab_size)
    out = serve_lib.generate(cfg, params, prompt, max_new=6, context_len=32)
    assert out.shape == (2, 14)
    assert bool((out[:, :8] == prompt).all())


def test_generate_padded_row_matches_solo():
    """Ragged-batch regression (the documented footgun): with ``lengths``
    a right-padded row must continue from its own last real token —
    identical to serving the same prompt alone — instead of attending to
    pad tokens as context."""
    cfg = configs.get_reduced("qwen2-1.5b")
    params = transformer.init_params(cfg, jax.random.key(7))
    rng = np.random.default_rng(7)
    short = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    long_ = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    S, max_new = 12, 6
    batch = np.zeros((2, S), np.int32)
    batch[0, :5] = short
    batch[1] = long_
    out = np.asarray(serve_lib.generate(
        cfg, params, jnp.asarray(batch), max_new=max_new,
        context_len=S + max_new, lengths=np.array([5, 12])))
    solo = np.asarray(serve_lib.generate(
        cfg, params, jnp.asarray(short[None]), max_new=max_new,
        context_len=S + max_new))
    np.testing.assert_array_equal(out[0, 5:5 + max_new], solo[0, 5:])
    np.testing.assert_array_equal(out[1, :12], long_)     # prompt intact
    # the long (unpadded) row must behave exactly like the lengths-free path
    plain = np.asarray(serve_lib.generate(
        cfg, params, jnp.asarray(long_[None]), max_new=max_new,
        context_len=S + max_new))
    np.testing.assert_array_equal(out[1], plain[0])


def test_generate_lengths_rejects_recurrent_stacks():
    cfg = configs.get_reduced("falcon-mamba-7b")
    params = transformer.init_params(cfg, jax.random.key(8))
    batch = np.zeros((2, 8), np.int32)
    with pytest.raises(ValueError, match="attention-only"):
        serve_lib.generate(cfg, params, jnp.asarray(batch), max_new=2,
                           lengths=np.array([4, 8]))
    # Equal lengths == nothing padded: the lockstep Batcher always sends
    # lengths, and that must keep working for every decode-capable stack.
    out = serve_lib.generate(cfg, params, jnp.asarray(batch), max_new=2,
                             lengths=np.array([8, 8]))
    assert out.shape == (2, 10)


def test_sliding_window_cache_ring_wraps():
    """Decode far past the window: ring cache must stay consistent."""
    cfg = configs.get_reduced("mixtral-8x7b")  # window=16
    params = transformer.init_params(cfg, jax.random.key(5))
    toks = jax.random.randint(jax.random.key(6), (1, 24), 0, cfg.vocab_size)
    # Prefill 24 tokens with a 64-token context: window keeps last 16.
    _, state = transformer.prefill(cfg, params, tokens=toks, context_len=64)
    step = jax.jit(serve_lib.make_serve_step(cfg))
    tok = toks[:, -1:]
    for i in range(20):  # decode well past one window
        tok, state = step(params, state, tok, jnp.int32(24 + i))
    assert bool(jnp.isfinite(tok).all())


def test_data_pipeline_deterministic_and_sharded():
    cfg = DataConfig(seq_len=16, batch_size=4, vocab_size=97, seed=3)
    a = next(iter(make_source(cfg, host_id=0, num_hosts=2)))
    b = next(iter(make_source(cfg, host_id=0, num_hosts=2)))
    c = next(iter(make_source(cfg, host_id=1, num_hosts=2)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_yields_batches():
    cfg = DataConfig(seq_len=8, batch_size=2, vocab_size=50)
    pf = Prefetcher(make_source(cfg), depth=2)
    batches = [next(pf) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    pf.close()


def test_byte_corpus(tmp_path):
    path = tmp_path / "corpus.txt"
    path.write_bytes(b"the quick brown fox jumps over the lazy dog " * 50)
    cfg = DataConfig(seq_len=16, batch_size=2, vocab_size=256, kind="bytes",
                     path=str(path))
    batch = next(iter(make_source(cfg)))
    assert batch["tokens"].shape == (2, 16)
    assert batch["tokens"].max() < 256
