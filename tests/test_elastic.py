"""Elastic checkpoint round-trips: save under mesh A, restore under mesh B.

The recovery contract of ckpt/elastic.py (paper §6 + our scale-out): the
full learner state — params, optimizer moments, AND the int8
error-feedback residual — restores bit-exactly onto a *different* mesh,
both growing (more devices than at save time) and shrinking. Exercised on
a transformer (qwen2) and a recurrent (recurrentgemma) reduced config, so
both param-tree families go through the sharding rules.

Like tests/test_distributed.py, each case runs in a subprocess with 8
placeholder devices (the main test process must keep seeing 1 device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh

from repro import configs
from repro.ckpt import checkpoint
from repro.ckpt.elastic import reshard, restore_elastic
from repro.models import transformer
from repro.train.optimizer import init_opt_state

devs = np.array(jax.devices())
mesh_small = Mesh(devs[:4].reshape(2, 2), ("data", "model"))   # 4 devices
mesh_big = Mesh(devs.reshape(2, 4), ("data", "model"))         # 8 devices


def state_tree(arch):
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(cfg, jax.random.key(0))
    return {"params": params, "opt": init_opt_state(params),
            "ef": jax.tree.map(
                lambda x: np.zeros(x.shape, np.float32), params)}


def assert_bit_exact(expect, got):
    flat_e = jax.tree_util.tree_flatten_with_path(expect)[0]
    flat_g = jax.tree_util.tree_flatten_with_path(got)[0]
    assert len(flat_e) == len(flat_g) and len(flat_e) > 0
    for (pe, e), (pg, g) in zip(flat_e, flat_g):
        assert pe == pg, (pe, pg)
        a = np.asarray(jax.device_get(e))
        b = np.asarray(jax.device_get(g))
        assert a.dtype == b.dtype, (pe, a.dtype, b.dtype)
        np.testing.assert_array_equal(a, b, err_msg=str(pe))
    return len(flat_e)
"""


def _run(body: str) -> str:
    code = _PRELUDE + textwrap.dedent(body)
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-2b"])
def test_restore_roundtrip_across_mesh_resize(arch, tmp_path):
    """Grow (4 -> 8 devices) and shrink (8 -> 4): same logical values,
    new placement, for the whole {params, opt, ef} learner state."""
    out = _run(f"""
    tree = state_tree("{arch}")

    # -- grow: saved on the small mesh, restored onto the big one --------
    placed = reshard(tree, mesh_small)
    d = os.path.join("{tmp_path}", "grow")
    checkpoint.save(placed, d)
    grown = restore_elastic(d, like=tree, new_mesh=mesh_big)
    n = assert_bit_exact(tree, grown)
    for leaf in jax.tree.leaves(grown):
        assert leaf.sharding.mesh.devices.size == 8
    print("GROW_OK", n)

    # -- shrink: saved on the big mesh, restored onto the small one ------
    placed = reshard(tree, mesh_big)
    d = os.path.join("{tmp_path}", "shrink")
    checkpoint.save(placed, d)
    shrunk = restore_elastic(d, like=tree, new_mesh=mesh_small)
    n = assert_bit_exact(tree, shrunk)
    for leaf in jax.tree.leaves(shrunk):
        assert leaf.sharding.mesh.devices.size == 4
    print("SHRINK_OK", n)
    """)
    assert "GROW_OK" in out and "SHRINK_OK" in out
    # Same leaf count both directions: nothing silently dropped.
    n_grow = int(out.split("GROW_OK")[1].split()[0])
    n_shrink = int(out.split("SHRINK_OK")[1].split()[0])
    assert n_grow == n_shrink > 0


def test_fill_missing_supplies_ef_residual_on_old_checkpoints(tmp_path):
    """Versions published before the error-feedback residual existed
    restore across a resize: the missing 'ef' subtree comes from ``like``
    (the caller's zero residual), everything present stays bit-exact."""
    out = _run(f"""
    tree = state_tree("qwen2-1.5b")
    old = {{"params": tree["params"], "opt": tree["opt"]}}  # pre-EF schema
    d = os.path.join("{tmp_path}", "old")
    checkpoint.save(reshard(old, mesh_small), d)

    try:
        restore_elastic(d, like=tree, new_mesh=mesh_big)
        print("STRICT_RAISED False")
    except Exception:
        print("STRICT_RAISED True")

    got = restore_elastic(d, like=tree, new_mesh=mesh_big,
                          fill_missing=True)
    assert_bit_exact(tree["params"], got["params"])
    assert_bit_exact(tree["opt"], got["opt"])
    for leaf in jax.tree.leaves(got["ef"]):
        assert float(np.abs(np.asarray(jax.device_get(leaf))).max()) == 0.0
    print("FILLED_OK")
    """)
    assert "STRICT_RAISED True" in out    # absent leaves are not silent
    assert "FILLED_OK" in out
