"""Serve-fabric control plane: registry membership/liveness, least-loaded
routing, mid-request failover, backpressure, and router re-discovery.

Fast tests drive the real Router/Registry/Heartbeater over the real
courier inproc transport against fake replicas (no jax); the end-to-end
fabric with real engines (including the mid-run replica kill) runs in
tests/test_examples.py.
"""

import threading
import time
import uuid

import numpy as np
import pytest

from repro.core import courier
from repro.core.discovery import Heartbeater, Registry
from repro.serve.router import Overloaded, Router, is_overloaded


class FakeReplica:
    """EngineServer-shaped service: generate/load/health, controllable."""

    def __init__(self, block: threading.Event = None,
                 fail_with: BaseException = None, num_slots: int = 8):
        self.block = block
        self.fail_with = fail_with
        self.num_slots = num_slots
        self.calls = 0

    def generate(self, prompt, max_new=None):
        self.calls += 1
        if self.fail_with is not None:
            raise self.fail_with
        if self.block is not None:
            assert self.block.wait(timeout=30)
        return np.concatenate([np.asarray(prompt, np.int32), [7]])

    def load(self):
        return {"num_slots": self.num_slots, "free_slots": self.num_slots,
                "queue_depth": 0, "ewma_us_per_token": 100.0}

    def health(self):
        return {"status": "ok"}


@pytest.fixture
def fabric():
    """A Registry plus a factory that registers fake replicas over the
    real inproc courier transport; everything unregisters on teardown."""
    registry = Registry(ttl_s=5.0)
    names = []

    def add(replica, load=None, name=None):
        name = name or f"rep-{uuid.uuid4().hex[:8]}"
        courier.inprocess.register(name, replica)
        names.append(name)
        registry.register(name, f"inproc://{name}",
                          load if load is not None else replica.load())
        return name

    yield registry, add
    for name in names:
        courier.inprocess.unregister(name)


def make_router(registry, **kw):
    kw.setdefault("refresh_s", 0.05)
    kw.setdefault("startup_wait_s", 2.0)
    return Router(registry, **kw)


# -- registry ----------------------------------------------------------------

def test_registry_missed_beats_evict():
    # Generous TTL-vs-sleep margins: a loaded host oversleeping must not
    # age 'a' past the TTL between its beats.
    reg = Registry(ttl_s=0.6)
    reg.register("a", "inproc://a")
    reg.register("b", "inproc://b")
    assert [r["name"] for r in reg.lookup()["replicas"]] == ["a", "b"]
    g0 = reg.lookup()["generation"]
    time.sleep(0.4)
    assert reg.heartbeat("a")                     # refresh a only
    time.sleep(0.4)                               # b's last beat is now stale
    view = reg.lookup()
    assert [r["name"] for r in view["replicas"]] == ["a"]
    assert view["generation"] > g0                # eviction bumped it
    assert not reg.heartbeat("b")                 # evicted: told to re-register
    reg.register("b", "inproc://b")
    assert len(reg.lookup()["replicas"]) == 2


def test_registry_report_failure_and_recover():
    reg = Registry(ttl_s=5.0)
    reg.register("a", "inproc://a")
    assert reg.report_failure("a")
    assert reg.lookup()["replicas"] == []
    assert not reg.report_failure("a")            # already gone
    assert not reg.heartbeat("a")                 # live replica re-registers:
    reg.register("a", "inproc://a")
    assert [r["name"] for r in reg.lookup()["replicas"]] == ["a"]


def test_registry_heartbeat_carries_load():
    reg = Registry(ttl_s=5.0)
    reg.register("a", "inproc://a", {"free_slots": 1})
    reg.heartbeat("a", {"free_slots": 7})
    (rep,) = reg.lookup()["replicas"]
    assert rep["load"]["free_slots"] == 7
    assert rep["age_s"] < 1.0


def test_heartbeater_keeps_alive_and_reregisters():
    reg = Registry(ttl_s=0.3)
    hb = Heartbeater(reg, "x", "inproc://x", period_s=0.05,
                     load_fn=lambda: {"free_slots": 3}).start()
    try:
        time.sleep(0.6)                           # several TTLs: still live
        (rep,) = reg.lookup()["replicas"]
        assert rep["load"]["free_slots"] == 3
        reg.report_failure("x")                   # wrongly reported...
        time.sleep(0.2)                           # ...re-registers in a beat
        assert [r["name"] for r in reg.lookup()["replicas"]] == ["x"]
    finally:
        hb.stop()
    assert reg.lookup()["replicas"] == []         # graceful deregistration


# -- routing -----------------------------------------------------------------

def test_router_routes_to_least_loaded(fabric):
    registry, add = fabric
    busy, idle = FakeReplica(), FakeReplica()
    add(busy, load={"num_slots": 8, "free_slots": 0, "queue_depth": 6})
    add(idle, load={"num_slots": 8, "free_slots": 8, "queue_depth": 0})
    with make_router(registry) as router:
        for _ in range(4):
            out = router.submit(np.arange(3, dtype=np.int32))
            assert out[-1] == 7
    assert idle.calls == 4 and busy.calls == 0


def test_router_spreads_ties_by_inflight(fabric):
    """Between heartbeats the router's own in-flight counts dominate:
    equal reported loads must not pin every request to one replica."""
    registry, add = fabric
    gate = threading.Event()
    a, b = FakeReplica(block=gate), FakeReplica(block=gate)
    add(a)
    add(b)
    with make_router(registry) as router:
        futs = [courier.inprocess.shared_pool().submit(
            router.submit, np.arange(2, dtype=np.int32)) for _ in range(6)]
        deadline = time.monotonic() + 5
        while a.calls + b.calls < 6 and time.monotonic() < deadline:
            time.sleep(0.01)
        gate.set()
        for f in futs:
            f.result(timeout=30)
    assert a.calls == 3 and b.calls == 3


def test_router_failover_onto_sibling_zero_lost(fabric):
    """A replica dying mid-request (RPC raises) is retried on a sibling
    and evicted registry-wide; the caller never sees the failure."""
    registry, add = fabric
    dead = FakeReplica(fail_with=RuntimeError("engine stopped"))
    live = FakeReplica()
    # The dead replica advertises the *better* load, so it is picked first.
    dead_name = add(dead, load={"num_slots": 8, "free_slots": 8,
                                "queue_depth": 0})
    add(live, load={"num_slots": 8, "free_slots": 2, "queue_depth": 3})
    with make_router(registry) as router:
        outs = [router.submit(np.arange(4, dtype=np.int32))
                for _ in range(5)]
        stats = router.stats()
    assert all(o[-1] == 7 for o in outs)          # zero lost
    assert dead.calls >= 1 and live.calls == 5
    assert stats["failovers"] >= 1
    assert stats["first_failover_done_s"] is not None   # recovery marker
    names = [r["name"] for r in registry.lookup()["replicas"]]
    assert dead_name not in names                 # evicted for everyone


def test_router_request_errors_are_not_retried(fabric):
    registry, add = fabric
    rep = FakeReplica(fail_with=ValueError("prompt too long"))
    name = add(rep)
    with make_router(registry) as router:
        with pytest.raises(ValueError, match="too long"):
            router.submit(np.arange(4, dtype=np.int32))
        assert router.stats()["request_errors"] == 1
    assert rep.calls == 1                         # exactly one attempt
    names = [r["name"] for r in registry.lookup()["replicas"]]
    assert name in names                          # the replica is healthy


def test_router_overloaded_when_all_queues_full(fabric):
    registry, add = fabric
    gate = threading.Event()
    rep = FakeReplica(block=gate, num_slots=1)
    add(rep, load={"num_slots": 1, "free_slots": 1, "queue_depth": 0})
    with make_router(registry) as router:
        # budget = num_slots + queue slack = 2: fill it with blocked calls.
        futs = [courier.inprocess.shared_pool().submit(
            router.submit, np.arange(2, dtype=np.int32)) for _ in range(2)]
        deadline = time.monotonic() + 5
        while router.load()["inflight"] < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        with pytest.raises(Overloaded):
            router.submit(np.arange(2, dtype=np.int32))
        try:
            raise Overloaded("x")
        except Overloaded as exc:
            assert is_overloaded(exc)
        gate.set()
        for f in futs:                            # the admitted ones finish
            assert f.result(timeout=30)[-1] == 7
        assert router.stats()["overloaded"] >= 1


def test_router_server_side_timeout_excludes_without_evicting(fabric):
    """A timeout shipped back wrapped in the courier envelope means slow,
    not dead: the request retries a sibling, but the slow replica stays
    registered (the module's 'slow is not dead' policy)."""
    from concurrent import futures as cf
    from repro.core.courier.serialization import RemoteError
    registry, add = fabric
    wrapped = RemoteError("remote call failed:\n...")
    wrapped.__cause__ = cf.TimeoutError()
    slow = FakeReplica(fail_with=wrapped)
    fast = FakeReplica()
    slow_name = add(slow, load={"num_slots": 8, "free_slots": 8,
                                "queue_depth": 0})
    add(fast, load={"num_slots": 8, "free_slots": 2, "queue_depth": 3})
    with make_router(registry) as router:
        out = router.submit(np.arange(3, dtype=np.int32))
        stats = router.stats()
    assert out[-1] == 7
    assert stats["retries"] == 1 and stats["failovers"] == 0
    names = [r["name"] for r in registry.lookup()["replicas"]]
    assert slow_name in names                     # never evicted


def test_router_ttl_eviction_drains_inflight(fabric):
    """A replica that drops out of the registry mid-request (TTL
    eviction of a stalled-but-live node) must not have its transport
    closed under the in-flight request: the router drains it — no new
    dispatches, close deferred to the last release."""
    registry, add = fabric
    gate = threading.Event()
    rep = FakeReplica(block=gate)
    name = add(rep)
    closed = []

    def factory(endpoint):
        client = courier.client_for(endpoint)

        class Recorder:
            futures = client.futures

            def close(self):
                closed.append(endpoint)
                client.close()
        return Recorder()

    with make_router(registry, client_factory=factory,
                     refresh_s=0.05) as router:
        fut = courier.inprocess.shared_pool().submit(
            router.submit, np.arange(2, dtype=np.int32))
        deadline = time.monotonic() + 5
        while rep.calls < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        registry.report_failure(name)             # TTL-style eviction
        deadline = time.monotonic() + 5
        while router.health()["replicas"] > 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert closed == []                       # in flight: not closed
        gate.set()
        assert fut.result(timeout=30)[-1] == 7    # request unharmed
        deadline = time.monotonic() + 5
        while not closed and time.monotonic() < deadline:
            time.sleep(0.01)
        assert closed                             # drained -> closed


def test_router_stale_incarnation_failure_spares_reregistered(fabric):
    """A failure surfacing from an old, drained incarnation must not
    evict (or close the client of) the healthy replica that re-registered
    under the same name in the meantime."""
    registry, add = fabric
    gate = threading.Event()

    class Flaky(FakeReplica):
        def generate(self, prompt, max_new=None):
            self.calls += 1
            if self.calls == 1:               # the in-flight "old" call
                assert gate.wait(timeout=30)
                raise RuntimeError("engine stopped")
            return super().generate(prompt, max_new)

    rep = Flaky()
    name = add(rep)
    with make_router(registry, refresh_s=0.05) as router:
        fut = courier.inprocess.shared_pool().submit(
            router.submit, np.arange(2, dtype=np.int32))
        deadline = time.monotonic() + 5
        while rep.calls < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        registry.report_failure(name)         # TTL-style eviction...
        deadline = time.monotonic() + 5
        while router.health()["replicas"] > 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        registry.register(name, f"inproc://{name}", rep.load())  # ...recovery
        deadline = time.monotonic() + 5
        while router.health()["replicas"] < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        gate.set()                            # stale incarnation now fails
        with pytest.raises(Overloaded):       # same name was already tried
            fut.result(timeout=30)
        # The re-registered incarnation survived the stale failure:
        assert router.health()["replicas"] == 1
        assert [r["name"] for r in registry.lookup()["replicas"]] == [name]
        assert router.submit(np.arange(2, dtype=np.int32))[-1] == 7


def test_router_all_replicas_dead_is_overloaded(fabric):
    """When failover drops every replica, the caller gets the typed
    retry-later signal (a stalled replica re-registers next beat), not
    the dead replica's own error."""
    registry, add = fabric
    rep = FakeReplica(fail_with=RuntimeError("engine stopped"))
    add(rep)
    with make_router(registry) as router:
        with pytest.raises(Overloaded, match="no healthy replica"):
            router.submit(np.arange(2, dtype=np.int32))
    assert rep.calls == 1
    assert registry.lookup()["replicas"] == []    # evicted for everyone


def test_router_no_replicas_fails_fast(fabric):
    registry, _ = fabric
    with make_router(registry, startup_wait_s=0.2) as router:
        t0 = time.monotonic()
        with pytest.raises(Overloaded, match="no live replicas"):
            router.submit(np.arange(2, dtype=np.int32))
        assert time.monotonic() - t0 < 5.0


def test_router_restart_rediscovers_live_replicas(fabric):
    registry, add = fabric
    rep = FakeReplica()
    add(rep)
    router = make_router(registry)
    assert router.submit(np.arange(2, dtype=np.int32))[-1] == 7
    router.close()
    # A fresh router (restart) finds the live set from the registry alone.
    with make_router(registry) as reborn:
        assert reborn.submit(np.arange(2, dtype=np.int32))[-1] == 7
        assert reborn.health()["replicas"] == 1
    assert rep.calls == 2


def test_router_discovers_late_replicas(fabric):
    """Launch is asynchronous: a router that starts before any replica
    registered must pick them up within its startup grace."""
    registry, add = fabric
    rep = FakeReplica()

    def late_add():
        time.sleep(0.2)
        add(rep)

    t = threading.Thread(target=late_add)
    t.start()
    try:
        with make_router(registry, startup_wait_s=5.0) as router:
            assert router.submit(np.arange(2, dtype=np.int32))[-1] == 7
    finally:
        t.join()


# -- coalesced dispatch -------------------------------------------------------

def test_coalesced_dispatch_batches_frames(fabric):
    """While the dispatcher is busy sending one frame, concurrent submits
    pile up behind it and leave as ONE batch_call frame — every caller
    still gets its own correct reply."""
    registry, add = fabric
    rep = FakeReplica(num_slots=32)
    add(rep, load={"num_slots": 32, "free_slots": 32, "queue_depth": 0})
    frames = []

    class SlowClient:
        """Transport wrapper that makes each frame send take a while —
        the window in which arrivals coalesce."""

        def __init__(self, inner):
            self._inner = inner

        @property
        def futures(self):
            return self

        def batch_call(self, calls):
            frames.append(len(calls))
            time.sleep(0.08)
            return self._inner.futures.batch_call(calls)

        def close(self):
            self._inner.close()

    factory = lambda ep: SlowClient(courier.client_for(ep))  # noqa: E731
    with make_router(registry, client_factory=factory) as router:
        results = [None] * 8

        def call(i):
            results[i] = router.submit(np.arange(i + 1, dtype=np.int32))

        threads = [threading.Thread(target=call, args=(i,))
                   for i in range(8)]
        threads[0].start()
        time.sleep(0.03)                  # frame 1 is in flight
        for th in threads[1:]:
            th.start()
        for th in threads:
            th.join(timeout=30)
        s = router.stats()
    for i, out in enumerate(results):
        np.testing.assert_array_equal(
            out, np.concatenate([np.arange(i + 1, dtype=np.int32), [7]]))
    assert s["dispatches"] == 8
    assert s["frames"] < s["dispatches"]          # something coalesced
    assert max(frames) >= 2
    assert s["mean_calls_per_frame"] > 1.0
    assert s["coalesced_calls"] >= 2


def test_coalesced_frame_failure_fans_out_and_fails_over(fabric):
    """A frame-level transport death must fan the error out to every call
    in the frame and feed the normal failover path: the request completes
    on the sibling and the dead replica is evicted registry-wide."""
    registry, add = fabric
    good = FakeReplica()
    add(good)
    # More attractive load -> always picked first; its transport is dead.
    dead_name = add(FakeReplica(),
                    load={"num_slots": 8, "free_slots": 100,
                          "queue_depth": 0},
                    name=f"dead-{uuid.uuid4().hex[:8]}")

    class DeadClient:
        @property
        def futures(self):
            return self

        def batch_call(self, calls):
            raise ConnectionError("transport down")

        def close(self):
            pass

    factory = lambda ep: (DeadClient() if f"inproc://{dead_name}" == ep  # noqa: E731
                          else courier.client_for(ep))
    with make_router(registry, client_factory=factory) as router:
        out = router.submit(np.arange(3, dtype=np.int32))
        np.testing.assert_array_equal(out, [0, 1, 2, 7])
        assert router.stats()["failovers"] >= 1
    assert good.calls == 1
    names = [r["name"] for r in registry.lookup()["replicas"]]
    assert dead_name not in names                 # evicted registry-wide


def test_router_score_caps_admission_headroom_at_free_pages(fabric):
    """A paged replica advertising many free rows but a drained page pool
    must lose to a sibling with real page headroom: the score caps free
    slots at free_pages / pages_per_request."""
    registry, add = fabric
    roomy, starved = FakeReplica(), FakeReplica()
    add(roomy, load={"num_slots": 4, "free_slots": 2, "queue_depth": 0})
    add(starved, load={"num_slots": 4, "free_slots": 4, "queue_depth": 0,
                       "free_pages": 2, "pages_per_request_ewma": 4.0})
    with make_router(registry) as router:
        out = router.submit(np.arange(3, dtype=np.int32))
        np.testing.assert_array_equal(out, [0, 1, 2, 7])
    assert roomy.calls == 1 and starved.calls == 0
