import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
import re, dataclasses, collections
from repro import configs
from repro.launch import cells as cells_lib
from repro.models import transformer, scan_utils, attention, ssm
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import _SHAPE_RE, _DTYPE_BYTES

arch, shape_name = sys.argv[1], sys.argv[2]
if len(sys.argv) > 3 and sys.argv[3] == "bf16":
    ssm.SCAN_DTYPE = "bfloat16"
cfg = configs.get(arch)
shape = cells_lib.SHAPES[shape_name]
mesh = make_production_mesh()
plan = cells_lib.plan_cell(cfg, shape, mesh)
plan = dataclasses.replace(plan, unroll_micro=True)
transformer.SCAN_UNROLL_THRESHOLD = 4
scan_utils.FORCE_SINGLE_CHUNK = True
attention.CHUNK_MODE = "unrolled"
pcfg = dataclasses.replace(cfg, num_layers=len(cfg.pattern))
cell = cells_lib.build_cell(pcfg, shape, mesh, plan=plan)
compiled = cells_lib.lower_cell(cell, mesh).compile()
ca = compiled.cost_analysis()
print("total bytes accessed:", f"{ca.get('bytes accessed'):.3e}", "flops:", f"{ca.get('flops'):.3e}")
# rank ops by result bytes (per occurrence), grouped by opcode+shape
buckets = collections.Counter()
op_re = re.compile(r"=\s*(\(?[a-z0-9_]+\[[0-9,]*\][^)=]*?\)?)\s+([a-z][a-z0-9_-]*)\(")
for line in compiled.as_text().splitlines():
    m = op_re.search(line)
    if not m: continue
    shapes_str, op = m.group(1), m.group(2)
    size = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES: continue
        n = 1
        for d in dims.split(","):
            if d.strip(): n *= int(d)
        size += n * _DTYPE_BYTES[dtype]
    buckets[(op, shapes_str[:48])] += size
for (op, shp), b in buckets.most_common(12):
    print(f"{b:.3e} {op:22s} {shp}")
