import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
import re, dataclasses, collections
from repro import configs
from repro.launch import cells as cells_lib, dryrun
from repro.launch.mesh import make_production_mesh
from repro.models import transformer, scan_utils, attention
from repro.roofline import analysis

arch, shape_name, nm = sys.argv[1], sys.argv[2], int(sys.argv[3])
cfg = configs.get(arch)
shape = cells_lib.SHAPES[shape_name]
mesh = make_production_mesh()
plan = cells_lib.plan_cell(cfg, shape, mesh)
plan = dataclasses.replace(plan, num_microbatches=nm, unroll_micro=True)

transformer.SCAN_UNROLL_THRESHOLD = 4
scan_utils.FORCE_SINGLE_CHUNK = True
attention.CHUNK_MODE = "unrolled"
pcfg = dataclasses.replace(cfg, num_layers=2*len(cfg.pattern))
cell = cells_lib.build_cell(pcfg, shape, mesh, plan=plan)
compiled = cells_lib.lower_cell(cell, mesh).compile()
txt = compiled.as_text()

# rank collectives by wire bytes, keyed by (kind, shape)
buckets = collections.Counter()
for line in txt.splitlines():
    m = analysis._INSTR_RE.search(line)
    if not m: continue
    shapes_str, kind, sd = m.group(1), m.group(2), m.group(3)
    if sd == "-done": continue
    size = analysis._shape_bytes(shapes_str)
    g = analysis._group_size(line, mesh.size)
    if g <= 1: continue
    w = {"all-reduce": 2*size*(g-1)/g, "all-gather": size*(g-1)/g,
         "reduce-scatter": size*(g-1), "all-to-all": size*(g-1)/g,
         "collective-permute": size}[kind]
    buckets[(kind, shapes_str[:60], g)] += w
total = sum(buckets.values())
print(f"total wire bytes (2-superblock probe, nm={nm}): {total:.3e}")
for (kind, shp, g), w in buckets.most_common(12):
    print(f"{w:.3e} ({100*w/total:4.1f}%) {kind:18s} g={g:4d} {shp}")
