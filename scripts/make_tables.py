"""Regenerate the EXPERIMENTS.md dry-run/roofline tables from artifacts."""
import json, glob, os, sys

def load(mesh):
    rows = {}
    for f in glob.glob(f"artifacts/dryrun/{mesh}/*.json"):
        d = json.load(open(f))
        rows[(d["arch"], d["shape"])] = d
    return rows

single, multi = load("single"), load("multi")
shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
archs = sorted({a for a, _ in single})

print("### Dry-run matrix (status single-pod 16x16 / multi-pod 2x16x16, per-chip peak GB)\n")
print("| arch | " + " | ".join(shapes) + " |")
print("|---|" + "---|" * len(shapes))
for a in archs:
    cells = []
    for s in shapes:
        d1, d2 = single.get((a, s)), multi.get((a, s))
        if d1 is None:
            cells.append("—"); continue
        if d1["status"] == "skipped":
            cells.append("skip"); continue
        p1 = d1["memory"]["peak_estimate_gb"]
        p2 = d2["memory"]["peak_estimate_gb"] if d2 and d2["status"] == "ok" else None
        c = f"ok {p1:.1f}G / " + (f"ok {p2:.1f}G" if p2 is not None else d2["status"] if d2 else "—")
        cells.append(c)
    print(f"| {a} | " + " | ".join(cells) + " |")

print("\n### Roofline (single-pod, per-chip; seconds per step)\n")
print("| arch | shape | bound | compute_s | memory_s | collective_s | MFU | useful | collectives (AG/AR/RS/A2A/CP) |")
print("|---|---|---|---|---|---|---|---|---|")
for a in archs:
    for s in shapes:
        d = single.get((a, s))
        if not d or d["status"] != "ok" or "roofline" not in d:
            continue
        r = d["roofline"]; c = d["cost"]["collective_counts"]
        cc = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        print(f"| {a} | {s} | {r['bound']} | {r['compute_s']:.3f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['mfu']:.3f} | {r['useful_flops_ratio']:.2f} | {cc} |")

skips = [(a, s, single[(a, s)]["reason"]) for a in archs for s in shapes
         if (a, s) in single and single[(a, s)]["status"] == "skipped"]
print("\n### Skipped cells\n")
for a, s, r in skips:
    print(f"* `{a} × {s}` — {r}")
