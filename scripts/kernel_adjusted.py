"""Kernel-adjusted roofline: from the 1-superblock probe, classify HLO ops
whose tensors the Pallas kernels eliminate (attention S^2 logits, SSM/LRU
scan intermediates), and recompute the memory term without them.

Methodology: the flash/scan kernels keep those tensors in VMEM; their HBM
traffic becomes one streaming pass over kernel inputs/outputs, which is
<2% of what the XLA fallback moves and is folded into the remaining ops.
"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
import re, dataclasses, collections, json
from repro import configs
from repro.launch import cells as cells_lib
from repro.models import transformer, scan_utils, attention
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import _SHAPE_RE, _DTYPE_BYTES
from repro.roofline import hw

arch, shape_name = sys.argv[1], sys.argv[2]
cfg = configs.get(arch)
shape = cells_lib.SHAPES[shape_name]
mesh = make_production_mesh()
plan = dataclasses.replace(cells_lib.plan_cell(cfg, shape, mesh), unroll_micro=True)
transformer.SCAN_UNROLL_THRESHOLD = 4
scan_utils.FORCE_SINGLE_CHUNK = True
attention.CHUNK_MODE = "unrolled"

op_re = re.compile(r"=\s*(\(?[a-z0-9_]+\[[0-9,]*\][^)=]*?\)?)\s+([a-z][a-z0-9_-]*)\(")
def shape_dims(s):
    out = []
    for dtype, dims in _SHAPE_RE.findall(s):
        d = tuple(int(x) for x in dims.split(",") if x.strip())
        out.append((dtype, d))
    return out

def kernelizable(dims_list):
    """Tensor shapes the Pallas kernels keep in VMEM. Deliberately strict:
    4-D [B,H,Sq,Sk] attention logits/probs only (3-D [B,S,F] MLP
    activations are NOT eliminated by flash), and [..,Di,N] scan elements
    with the exact SSM state size."""
    for dtype, d in dims_list:
        if cfg.ssm_state and len(d) >= 3 and d[-1] == cfg.ssm_state:
            return True                      # [.., Di_shard, N] scan elems
        if len(d) == 4 and d[-2] >= 1024 and d[-1] >= 1024:
            return True                      # [B, H, Sq, Sk] logits/probs
    return False

fracs = []
for nsb in (1, 2):
    pcfg = dataclasses.replace(cfg, num_layers=nsb * len(cfg.pattern))
    cell = cells_lib.build_cell(pcfg, shape, mesh, plan=plan)
    compiled = cells_lib.lower_cell(cell, mesh).compile()
    total = kern = 0
    for line in compiled.as_text().splitlines():
        m = op_re.search(line)
        if not m: continue
        dims_list = shape_dims(m.group(1))
        size = sum((_DTYPE_BYTES.get(dt, 0) * __import__("math").prod(d or (1,)))
                   for dt, d in dims_list)
        total += size
        if kernelizable(dims_list):
            kern += size
    fracs.append((total, kern))

# per-superblock kernelizable fraction from the delta
dt_tot = fracs[1][0] - fracs[0][0]
dt_kern = fracs[1][1] - fracs[0][1]
frac = dt_kern / dt_tot if dt_tot else 0.0
base = json.load(open(f"artifacts/dryrun/single/{arch}__{shape_name}.json"))
mt = base["roofline"]["memory_s"]
adj_mt = mt * (1 - frac)
terms = dict(base["roofline"])
step = max(terms["compute_s"], adj_mt, terms["collective_s"])
print(f"{arch} {shape_name}: kernelizable byte fraction per layer = {frac:.2f}")
print(f"memory term {mt:.2f}s -> kernel-adjusted {adj_mt:.2f}s; "
      f"step {terms['step_s']:.2f}s -> {step:.2f}s; "
      f"mfu {terms['mfu']:.4f} -> {terms['model_flops_per_device']/hw.PEAK_FLOPS_BF16/step:.4f}")
