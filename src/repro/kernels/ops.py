"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: real kernels on TPU, interpret-mode
execution elsewhere (this container is CPU-only — interpret mode runs the
kernel body in Python for correctness validation; see DESIGN.md §7).

``decode_attention`` is the one wrapper on a serving hot path (the engine
calls it every token), so it carries a backend-aware dispatch table
instead of a bare jit: the Pallas kernel on TPU, the pure-jnp oracle as a
real XLA executable everywhere else, with ``REPRO_FORCE_REF=1`` as the
production escape hatch. See README.md in this directory.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssm_scan as _ssm


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def _pick_block_l(L: int, want: int) -> int:
    """Largest divisor of L that is <= want (the kernel tiles L evenly)."""
    b = min(want, L)
    while L % b:
        b -= 1
    return b


def resolve_decode_impl(impl: Optional[str] = None,
                        interpret: Optional[bool] = None) -> str:
    """Dispatch rule for ``decode_attention``.

    Explicit ``impl`` wins (tests pin a path). Otherwise ``REPRO_FORCE_REF=1``
    forces the oracle (the escape hatch when a kernel miscompile is
    suspected in production), an explicit ``interpret`` flag selects the
    Pallas body (that flag only means something to the kernel), and the
    default is backend-driven: the real kernel on TPU, the jnp oracle —
    a fast native XLA executable, not Python interpret mode — elsewhere.

    NOTE: when called inside a traced function the choice is baked into
    that executable at trace time (env var and backend are host state);
    the serve engine keys its executable caches on the impl for this
    reason.
    """
    if impl is not None:
        if impl not in ("pallas", "ref"):
            raise ValueError(f"impl must be 'pallas' or 'ref', got {impl!r}")
        return impl
    if os.environ.get("REPRO_FORCE_REF", "") == "1":
        return "ref"
    if interpret is not None:
        return "pallas"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def _decode_pallas(q, k, v, valid, block_l: int, interpret: bool):
    return _dec.decode_attention(q, k, v, valid, block_l=block_l,
                                 interpret=interpret)


_decode_ref = jax.jit(_ref.decode_attention)


def decode_attention(q, k, v, valid, block_l: int = 512,
                     interpret: Optional[bool] = None,
                     impl: Optional[str] = None):
    """Backend-dispatched single-token attention (see resolve_decode_impl).

    q [B,H,dh]; k/v [B,L,KV,dh]; valid [B,L] bool -> [B,H,dh]. Both paths
    share one contract, including all-invalid rows -> zeros.
    """
    if resolve_decode_impl(impl, interpret) == "ref":
        return _decode_ref(q, k, v, valid)
    interpret = _auto_interpret() if interpret is None else interpret
    return _decode_pallas(q, k, v, valid,
                          _pick_block_l(k.shape[1], block_l), interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_decode_pallas(q, k_pages, v_pages, pages, valid, interpret: bool):
    return _dec.paged_decode_attention(q, k_pages, v_pages, pages, valid,
                                       interpret=interpret)


_paged_decode_ref = jax.jit(_ref.paged_decode_attention)


def paged_decode_attention(q, k_pages, v_pages, pages, valid,
                           interpret: Optional[bool] = None,
                           impl: Optional[str] = None):
    """Backend-dispatched paged decode attention (same rule as the flat
    path — resolve_decode_impl — so CPU CI exercises the identical
    dispatch wiring with the jnp oracle as the leaf).

    q [B,H,dh]; k/v pages [P,ps,KV,dh]; pages [B,n] int32; valid [B,n*ps]
    -> [B,H,dh]. The block size is the page itself: the kernel walks the
    page list one physical page per grid step via scalar prefetch.
    """
    if resolve_decode_impl(impl, interpret) == "ref":
        return _paged_decode_ref(q, k_pages, v_pages, pages, valid)
    interpret = _auto_interpret() if interpret is None else interpret
    return _paged_decode_pallas(q, k_pages, v_pages, pages, valid, interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rglru_scan(a, x, h0, block_s: int = 256, block_w: int = 128,
               interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _rg.rglru_scan(a, x, h0, block_s=block_s, block_w=block_w,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "block_d",
                                             "interpret"))
def ssm_scan(u, delta, A, B, C, D, h0, block_s: int = 128,
             block_d: int = 128, interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _ssm.ssm_scan(u, delta, A, B, C, D, h0, block_s=block_s,
                         block_d=block_d, interpret=interpret)
