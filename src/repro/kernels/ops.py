"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: real kernels on TPU, interpret-mode
execution elsewhere (this container is CPU-only — interpret mode runs the
kernel body in Python for correctness validation; see DESIGN.md §7).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssm_scan as _ssm


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def decode_attention(q, k, v, valid, block_l: int = 512,
                     interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _dec.decode_attention(q, k, v, valid, block_l=block_l,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rglru_scan(a, x, h0, block_s: int = 256, block_w: int = 128,
               interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _rg.rglru_scan(a, x, h0, block_s=block_s, block_w=block_w,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "block_d",
                                             "interpret"))
def ssm_scan(u, delta, A, B, C, D, h0, block_s: int = 128,
             block_d: int = 128, interpret: Optional[bool] = None):
    interpret = _auto_interpret() if interpret is None else interpret
    return _ssm.ssm_scan(u, delta, A, B, C, D, h0, block_s=block_s,
                         block_d=block_d, interpret=interpret)
