"""RG-LRU linear recurrence as a Pallas TPU kernel (RecurrentGemma).

h_t = a_t · h_{t-1} + x_t, per channel. The TPU-native decomposition:
channels map onto VPU lanes (grid over channel blocks of 128·k), the
sequence is blocked HBM->VMEM (grid minor dim, sequential), and the
carried state h lives in VMEM scratch across sequence blocks. Inside a
block the recurrence steps row-by-row with ``fori_loop`` — sequential in
S but fully vectorized across the channel lanes, which is how a linear
recurrence actually maps to the VPU (there is no MXU work here).

Gates (a = exp(-c·softplus(Λ)·r)) are computed outside: they're cheap
elementwise projections XLA fuses well; the kernel owns the part XLA does
badly — O(S) sequential dependency without materializing [B,S,W,...]
scan intermediates in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, h0_ref, y_ref, hlast_ref, h_scr, *,
                  block_s: int):
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)     # [bw]

    a = a_ref[0].astype(jnp.float32)                   # [bs, bw]
    x = x_ref[0].astype(jnp.float32)                   # [bs, bw]

    def step(i, h):
        h = a[i] * h + x[i]
        y_ref[0, i, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(js == ns - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rglru_scan(a: jax.Array, x: jax.Array, h0: jax.Array, *,
               block_s: int = 256, block_w: int = 128,
               interpret: bool = False):
    """a/x [B,S,W], h0 [B,W] -> (y [B,S,W], h_last [B,W])."""
    B, S, W = x.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0, (S, block_s, W, block_w)

    grid = (B, W // block_w, S // block_s)
    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, block_s, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, block_w), lambda b, w, s: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, block_w), lambda b, w, s: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), x.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
    return y, h_last
