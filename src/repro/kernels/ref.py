"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors its kernel's exact semantics (masking, ring layout,
accumulation dtypes) with straightforward jnp code. Kernel tests sweep
shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    window: Optional[int] = None,
                    sm_scale: Optional[float] = None) -> jax.Array:
    """q [B,Sq,H,dh], k/v [B,Sk,KV,dh] (H % KV == 0) -> [B,Sq,H,dh]."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    sm_scale = sm_scale if sm_scale is not None else dh ** -0.5
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)   # right-aligned queries
    k_pos = jnp.arange(Sk)
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    logits = jnp.where(ok[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array,
                     sm_scale: Optional[float] = None) -> jax.Array:
    """One-token attention against a cache.

    q [B,H,dh]; k/v [B,L,KV,dh]; valid [B,L] bool -> [B,H,dh].

    A row with no valid slot outputs zeros — the kernel's convention (its
    online-softmax accumulator never runs, so l=0 finalizes to 0), not the
    uniform-softmax mean a plain softmax over all-NEG_INF would give.
    """
    B, H, dh = q.shape
    KV = k.shape[2]
    sm_scale = sm_scale if sm_scale is not None else dh ** -0.5
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    logits = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid.any(axis=-1)[:, None, None], w, 0.0)
    out = jnp.einsum("bhl,blhd->bhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, pages: jax.Array,
                           valid: jax.Array,
                           sm_scale: Optional[float] = None) -> jax.Array:
    """One-token attention against a *paged* cache.

    q [B,H,dh]; k/v pages [P,ps,KV,dh]; pages [B,n] int32 (per-row page
    list); valid [B,n*ps] bool over *logical* slots -> [B,H,dh].

    Semantically: gather each row's pages into its logical [n*ps] cache
    view, then exactly ``decode_attention`` — including the all-invalid ->
    zeros contract. The Pallas kernel walks the page list block-by-block
    instead of materializing the gather.
    """
    B = q.shape[0]
    ps, KV, dh = k_pages.shape[1:]
    n = pages.shape[1]
    k = k_pages[pages].reshape(B, n * ps, KV, dh)
    v = v_pages[pages].reshape(B, n * ps, KV, dh)
    return decode_attention(q, k, v, valid, sm_scale)


def rglru_scan(a: jax.Array, x: jax.Array, h0: jax.Array) -> tuple:
    """Sequential linear recurrence h_t = a_t h_{t-1} + x_t (all fp32).

    a/x [B,S,W], h0 [B,W] -> (y [B,S,W], h_last [B,W]).
    """
    def step(h, ax):
        at, xt = ax
        h = at * h + xt
        return h, h

    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    x_t = jnp.moveaxis(x.astype(jnp.float32), 1, 0)
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, x_t))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_last


def ssm_scan(u: jax.Array, delta: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, D: jax.Array, h0: jax.Array) -> tuple:
    """Mamba-1 selective scan.

    u/delta [B,S,Di], A [Di,N], B/C [B,S,N], D [Di], h0 [B,Di,N]
    -> (y [B,S,Di], h_last [B,Di,N]).
    """
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, inp):
        ut, dt, Bt, Ct = inp
        dA = jnp.exp(dt[:, :, None] * A[None])          # [b,Di,N]
        dBu = (dt * ut)[:, :, None] * Bt[:, None, :]
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, Ct) + D * ut
        return h, y

    inp = (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(df, 1, 0),
           jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), inp)
    return jnp.moveaxis(ys, 0, 1).astype(u.dtype), h_last
