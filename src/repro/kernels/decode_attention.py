"""Flash-decode: single-token attention over a long KV cache (Pallas TPU).

The ``decode_32k`` / ``long_500k`` serving shapes are dominated by
streaming the KV cache once per new token; this kernel blocks the cache
HBM->VMEM along L with online-softmax state in VMEM scratch, so HBM
traffic is exactly one pass over K and V (the roofline minimum for
decode). Ring-buffer validity (which slots hold live tokens, window
eviction) arrives as a precomputed ``valid`` mask — the kernel is layout
agnostic. GQA is native via the index_map (h // group).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, sm_scale: float):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[0] != 0                        # [bl]

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [1, dh]
        k = k_ref[0, 0].astype(jnp.float32)          # [bl, dh]
        v = v_ref[0, 0].astype(jnp.float32)          # [bl, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                              # [1, bl]
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_scr[...]                           # [1]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *,
                     sm_scale: Optional[float] = None,
                     block_l: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q [B,H,dh]; k/v [B,L,KV,dh]; valid [B,L] (bool/int) -> [B,H,dh]."""
    B, H, dh = q.shape
    L, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    sm_scale = sm_scale if sm_scale is not None else dh ** -0.5
    block_l = min(block_l, L)
    assert L % block_l == 0, (L, block_l)

    qt = q[:, :, None, :]                     # [B, H, 1, dh]
    kt = k.transpose(0, 2, 1, 3)              # [B, KV, L, dh]
    vt = v.transpose(0, 2, 1, 3)
    valid_i = valid.astype(jnp.int32)

    grid = (B, H, L // block_l)
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_l, dh),
                         lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_l, dh),
                         lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, block_l), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, valid_i)
    return out[:, :, 0, :]


def _paged_kernel(pages_ref, q_ref, k_ref, v_ref, valid_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, sm_scale: float):
    # Same online-softmax body as _decode_kernel; pages_ref is consumed by
    # the index maps (scalar prefetch), not the body.
    del pages_ref
    _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, sm_scale=sm_scale)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, pages: jax.Array,
                           valid: jax.Array, *,
                           sm_scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """Flash-decode over a paged KV pool.

    q [B,H,dh]; k/v pages [P,ps,KV,dh]; pages [B,n] int32; valid [B,n*ps]
    over logical slots -> [B,H,dh].

    The natural block is one page: grid step (b, h, j) streams row b's
    j-th *logical* page, and the page list rides in as a scalar-prefetch
    operand so the K/V index maps can point the DMA at the physical page
    ``pages[b, j]`` — the gather never materializes. The ``valid`` mask is
    logical-slot indexed, so its index map stays (b, j). Online-softmax
    state in VMEM scratch, identical to the flat kernel.
    """
    B, H, dh = q.shape
    P, ps, KV = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    n = pages.shape[1]
    assert valid.shape == (B, n * ps), (valid.shape, B, n, ps)
    assert H % KV == 0
    group = H // KV
    sm_scale = sm_scale if sm_scale is not None else dh ** -0.5

    qt = q[:, :, None, :]                       # [B, H, 1, dh]
    kt = k_pages.transpose(2, 0, 1, 3)          # [KV, P, ps, dh]
    vt = v_pages.transpose(2, 0, 1, 3)
    valid_i = valid.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, j, pg: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, dh),
                         lambda b, h, j, pg: (h // group, pg[b, j], 0, 0)),
            pl.BlockSpec((1, 1, ps, dh),
                         lambda b, h, j, pg: (h // group, pg[b, j], 0, 0)),
            pl.BlockSpec((1, ps), lambda b, h, j, pg: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, h, j, pg: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, dh), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), qt, kt, vt, valid_i)
    return out[:, :, 0, :]
