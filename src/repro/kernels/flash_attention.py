"""Flash attention as a Pallas TPU kernel.

TPU-native blocking: the grid iterates (batch, q-heads, Sq blocks, Sk
blocks) with the Sk dimension innermost and *sequential*; q/k/v tiles are
staged HBM->VMEM by BlockSpec, the running max/denominator/accumulator
(the online-softmax state) lives in VMEM scratch across Sk iterations, and
each (block_q × block_k) logits tile exists only in VMEM — this removes
the O(S²) HBM traffic that dominates the XLA fallback path's memory
roofline term. GQA is native: the K/V BlockSpec index_map folds the
query-head -> kv-head mapping (h // group) so kv tiles are fetched once
per group, not expanded.

Causal / sliding-window masking is applied from absolute positions;
fully-masked k-blocks are skipped via ``pl.when`` (block-level early
exit — the TPU analogue of warp-level skipping in CUDA flash kernels).

Block sizes default to (128, 128): the MXU is 128×128 and head_dim is a
multiple of 128 for every assigned arch except recurrentgemma (256, also
aligned) and the reduced smoke configs (handled by clamping).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Absolute positions of this tile (queries are right-aligned when
    # seq_q < seq_k, matching the decode/extension convention).
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q) + (seq_k - seq_q)
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)

    # Block-level skip: is any (q, k) pair in this tile visible?
    lo_q, hi_q = iq * block_q + (seq_k - seq_q), iq * block_q + block_q - 1 + (seq_k - seq_q)
    lo_k, hi_k = ik * block_k, ik * block_k + block_k - 1
    visible = True
    if causal:
        visible = jnp.logical_and(visible, lo_k <= hi_q)
    if window is not None:
        visible = jnp.logical_and(visible, hi_k > lo_q - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)           # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                               # [bq, bk]

        diff = q_pos[:, None] - k_pos[None, :]
        ok = jnp.ones_like(diff, dtype=jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, diff >= 0)
        if window is not None:
            ok = jnp.logical_and(ok, diff < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                            # [bq]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q [B,Sq,H,dh], k/v [B,Sk,KV,dh] -> [B,Sq,H,dh]. H % KV == 0."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    sm_scale = sm_scale if sm_scale is not None else dh ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)

    # Layout: [B, H, S, dh] so the head grid dim indexes a major axis.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=Sq, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)