"""Mamba-1 selective scan as a Pallas TPU kernel (Falcon-Mamba).

The CUDA reference keeps per-thread state in registers and relies on warp
shuffles; neither exists on TPU. The TPU-native layout instead:

  * channels (d_inner) map to VPU lanes — grid over channel blocks;
  * the SSM state h [block_d, N] lives in VMEM scratch (N=16 fits easily);
  * the sequence is blocked HBM->VMEM and stepped with ``fori_loop`` —
    sequential in S, vectorized over [block_d, N];
  * discretization (exp(Δ⊗A), Δu⊗B) happens *inside* the kernel, so the
    [B,S,D,N] tensors the pure-XLA associative scan materializes in HBM
    never exist — that 16× blow-up is exactly what made the XLA path
    memory-bound.

Inputs are the raw per-timestep quantities (u, Δ, B, C) plus the
per-channel constants (A, D).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(u_ref, d_ref, b_ref, c_ref, A_ref, D_ref, h0_ref,
                y_ref, hlast_ref, h_scr, *, block_s: int):
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)       # [bd, N]

    u = u_ref[0].astype(jnp.float32)                      # [bs, bd]
    delta = d_ref[0].astype(jnp.float32)                  # [bs, bd]
    Bc = b_ref[0].astype(jnp.float32)                     # [bs, N]
    Cc = c_ref[0].astype(jnp.float32)                     # [bs, N]
    A = A_ref[...].astype(jnp.float32)                    # [bd, N]
    Dd = D_ref[...].astype(jnp.float32)                   # [bd]

    def step(i, h):
        dA = jnp.exp(delta[i][:, None] * A)               # [bd, N]
        dBu = (delta[i] * u[i])[:, None] * Bc[i][None, :]
        h = dA * h + dBu
        y = jnp.sum(h * Cc[i][None, :], axis=1) + Dd * u[i]
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(js == ns - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def ssm_scan(u: jax.Array, delta: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, D: jax.Array, h0: jax.Array, *,
             block_s: int = 128, block_d: int = 128,
             interpret: bool = False):
    """u/delta [B,S,Di], A [Di,N], B/C [B,S,N], D [Di], h0 [B,Di,N]
    -> (y [B,S,Di], h_last [B,Di,N])."""
    Bb, S, Di = u.shape
    N = A.shape[1]
    block_s = min(block_s, S)
    block_d = min(block_d, Di)
    assert S % block_s == 0 and Di % block_d == 0

    grid = (Bb, Di // block_d, S // block_s)
    kernel = functools.partial(_ssm_kernel, block_s=block_s)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, s: (d, 0)),
            pl.BlockSpec((block_d,), lambda b, d, s: (d,)),
            pl.BlockSpec((1, block_d, N), lambda b, d, s: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, s: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, Di), u.dtype),
            jax.ShapeDtypeStruct((Bb, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(u, delta, B, C, A, D, h0)
    return y, h_last
