"""Serving steps: prefill and single-token decode with greedy/temperature
sampling. ``make_serve_step`` is what the dry-run lowers for the
``decode_32k`` / ``long_500k`` shapes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def make_sampler(temperature: float = 0.0, top_k: Optional[int] = None):
    """Build a jit-safe sampler: (logits [B,1,V], key) -> tokens [B,1].

    ``temperature`` / ``top_k`` are *static config* closed over the
    returned function, decided in Python before any trace — the previous
    ``sample_from_logits`` read ``temperature`` with Python truthiness,
    which throws the moment the value is a traced operand (as it would be
    inside the fused decode scan). temperature == 0.0 keeps the exact
    argmax guarantee; otherwise gumbel-max sampling, optionally truncated
    to the ``top_k`` highest logits.
    """
    temperature = float(temperature)

    def sample(logits: jax.Array, key: Optional[jax.Array] = None):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        x = logits.astype(jnp.float32)
        if top_k is not None:
            kth = jax.lax.top_k(x, top_k)[0][..., -1:]
            x = jnp.where(x < kth, -jnp.inf, x)
        noise = jax.random.gumbel(key, x.shape, jnp.float32)
        return jnp.argmax(x / temperature + noise, axis=-1).astype(jnp.int32)

    return sample


def sample_from_logits(logits: jax.Array, key: Optional[jax.Array],
                       temperature: float = 0.0) -> jax.Array:
    """logits [B,1,V] -> tokens [B,1] (compat shim over make_sampler)."""
    return make_sampler(temperature)(logits, key)


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0,
                    top_k: Optional[int] = None, attn_impl: str = "auto"):
    """(params, state, tokens [B,1], t) -> (next_tokens [B,1], new_state)."""
    sampler = make_sampler(temperature, top_k)

    def serve_step(params, state, tokens, t, key=None):
        logits, state = transformer.decode_step(cfg, params, state, tokens,
                                                t, attn_impl=attn_impl)
        return sampler(logits, key), state

    return serve_step


def make_fused_serve_step(cfg: ModelConfig, steps: int,
                          temperature: float = 0.0,
                          top_k: Optional[int] = None,
                          attn_impl: str = "auto"):
    """``steps`` decode+sample iterations fused into ONE executable.

    (params, state, tokens [B,1], t [B], key?) ->
        (token_block [B,steps], new_state, next_tokens [B,1], t + steps,
         next_key)

    The sampler and the per-row feed-token / position updates run inside a
    ``lax.scan``, so the PRNG key, ``tokens`` and ``t`` stay device
    residents and the host syncs once per window instead of once per
    token. Greedy (temperature 0) carries no key (``key=None`` round-trips
    as None). The token block is everything the host needs: EOS /
    ``max_new`` retirement is detected on the sync by slicing each row's
    block to its own stop point — bit-identical to stepping one token at a
    time, because the scan body IS the single-step path.
    """
    sampler = make_sampler(temperature, top_k)

    def fused(params, state, tokens, t, key=None, pages=None):
        # ``pages`` (paged KV mode) is read-only inside the window — a
        # loop invariant. Threading it into every scan step makes the
        # paged attention path walk the whole working KV through the
        # page table once per step per layer; for multi-step windows the
        # pool is instead materialized as the equivalent flat per-row
        # view ONCE here, the scan runs the flat step body
        # (bit-identical math — the paged oracle is gather + this same
        # computation), and the <= 2 pages per row the window's slots
        # cover scatter back at the end: one pool walk per window
        # instead of ``steps``. A K=1 window (drain tails,
        # sync_every=1) keeps the direct paged step — the view would
        # cost two pool copies for a single token, and the direct path
        # is the one the paged flash-decode kernel serves on TPU.
        use_view = pages is not None and steps > 1
        pool_state, t0 = state, t
        if use_view:
            state = transformer.paged_window_view(cfg, state, pages)
        step_pages = None if use_view else pages

        def body(carry, _):
            state, tok, t, key = carry
            if key is not None:
                key, sub = jax.random.split(key)
            else:
                sub = None
            logits, state = transformer.decode_step(cfg, params, state, tok,
                                                    t, attn_impl=attn_impl,
                                                    pages=step_pages)
            nxt = sampler(logits, sub)
            return (state, nxt, t + 1, key), nxt[:, 0]

        (state, tok, t, key), toks = jax.lax.scan(
            body, (state, tokens, t, key), None, length=steps)
        if use_view:
            state = transformer.paged_window_scatter(cfg, pool_state, state,
                                                     pages, t0, steps)
        return jnp.moveaxis(toks, 0, 1), state, tok, t, key

    return fused


def make_prefill(cfg: ModelConfig, context_len: Optional[int] = None):
    def prefill_step(params, tokens, memory=None, embeddings=None):
        return transformer.prefill(
            cfg, params, tokens=tokens, memory=memory, embeddings=embeddings,
            context_len=context_len)
    return prefill_step


# Jitted-executable caches: make_serve_step/make_prefill return fresh
# closures, so a bare jax.jit around them would recompile on EVERY
# generate() call — ~seconds per serving batch, dwarfing the actual step.
# Keyed on the full static config (cfg, temperature, top_k, attn_impl /
# context_len); ModelConfig is frozen. attn_impl MUST be part of the key:
# the kernel-vs-dense choice is baked in at trace time.
@functools.lru_cache(maxsize=None)
def _cached_step(cfg: ModelConfig, temperature: float,
                 top_k: Optional[int] = None, attn_impl: str = "auto"):
    return jax.jit(make_serve_step(cfg, temperature, top_k, attn_impl))


@functools.lru_cache(maxsize=None)
def cached_fused_step(cfg: ModelConfig, steps: int, temperature: float,
                      top_k: Optional[int] = None, attn_impl: str = "auto"):
    """Shared fused-window executables (engines come and go; the compiled
    K-step scan is reusable across instances). state/tokens/t are donated:
    the engine threads them through as device residents."""
    return jax.jit(make_fused_serve_step(cfg, steps, temperature, top_k,
                                         attn_impl),
                   donate_argnums=(1, 2, 3))


@functools.lru_cache(maxsize=None)
def _cached_prefill(cfg: ModelConfig, context_len: int):
    return jax.jit(make_prefill(cfg, context_len))


_MASKABLE = {"attn", "swa", "local", "xattn"}


def _check_ragged_supported(cfg: ModelConfig, S: int, context_len: int):
    kinds = set(cfg.pattern) | set(cfg.remainder)
    if kinds - _MASKABLE:
        raise ValueError(
            f"ragged generate (lengths=...) needs an attention-only stack; "
            f"{cfg.name} has {sorted(kinds - _MASKABLE)} blocks whose "
            "recurrent state would absorb the pad tokens. Serve those "
            "architectures through the engine (exact-length prefill) or "
            "with equal-length prompts.")
    if context_len < S or (cfg.window is not None and cfg.window < S):
        raise ValueError(
            f"ragged generate needs the KV ring (context_len={context_len}, "
            f"window={cfg.window}) to hold the padded prompt (S={S}): a "
            "shorter ring wraps pad K/V onto slots the position mask "
            "treats as valid history.")


def generate(cfg: ModelConfig, params, prompt: jax.Array, max_new: int,
             context_len: Optional[int] = None, temperature: float = 0.0,
             key: Optional[jax.Array] = None, memory=None,
             lengths: Optional[jax.Array] = None,
             top_k: Optional[int] = None, attn_impl: str = "auto"):
    """Convenience loop for examples/tests: prefill + greedy decode.

    prompt [B, S] -> tokens [B, S + max_new].

    ``lengths`` ([B] int, optional) marks the true length of each
    right-padded row. With it, row ``b``'s continuation is sampled from the
    logits at its own last real token and decoded at per-row positions
    ``lengths[b] + i`` — pad K/V beyond a row's length sits at ring slots
    the decode position mask rejects (and decode overwrites them in order),
    so a padded row matches the same prompt served alone instead of
    attending to pad tokens as context. Generated tokens land at
    ``out[b, lengths[b]:lengths[b]+max_new]``; the tail keeps the pad.
    """
    import numpy as np
    B, S = prompt.shape
    context_len = context_len or (S + max_new)
    if lengths is not None and bool((np.asarray(lengths) == S).all()):
        lengths = None          # nothing is padded: every stack serves this
    if lengths is not None:
        _check_ragged_supported(cfg, S, context_len)
        t0 = jnp.asarray(lengths, jnp.int32)
    else:
        t0 = jnp.full((B,), S, jnp.int32)
    if memory is None:
        logits, state = _cached_prefill(cfg, context_len)(params,
                                                          tokens=prompt)
    else:  # VLM memory is test-only; skip the executable cache
        logits, state = transformer.prefill(cfg, params, tokens=prompt,
                                            memory=memory,
                                            context_len=context_len)
    last_logits = jnp.take_along_axis(logits, (t0 - 1)[:, None, None], axis=1)
    sampler = make_sampler(temperature, top_k)
    last = sampler(last_logits, key)
    step = _cached_step(cfg, temperature, top_k, attn_impl)
    gen = [last]
    tok = last
    for i in range(max_new - 1):
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        tok, state = step(params, state, tok, t0 + i, sub)
        gen.append(tok)
    gen = jnp.concatenate(gen, axis=1)                     # [B, max_new]
    out = jnp.zeros((B, S + max_new), prompt.dtype).at[:, :S].set(prompt)
    cols = t0[:, None] + jnp.arange(max_new, dtype=jnp.int32)[None, :]
    return out.at[jnp.arange(B)[:, None], cols].set(gen)
