"""Serving steps: prefill and single-token decode with greedy/temperature
sampling. ``make_serve_step`` is what the dry-run lowers for the
``decode_32k`` / ``long_500k`` shapes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def sample_from_logits(logits: jax.Array, key: Optional[jax.Array],
                       temperature: float = 0.0) -> jax.Array:
    """logits [B,1,V] -> tokens [B,1]."""
    if temperature and key is not None:
        noise = jax.random.gumbel(key, logits.shape, jnp.float32)
        logits = logits.astype(jnp.float32) / temperature + noise
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """(params, state, tokens [B,1], t) -> (next_tokens [B,1], new_state)."""

    def serve_step(params, state, tokens, t, key=None):
        logits, state = transformer.decode_step(cfg, params, state, tokens, t)
        nxt = sample_from_logits(logits, key, temperature)
        return nxt, state

    return serve_step


def make_prefill(cfg: ModelConfig, context_len: Optional[int] = None):
    def prefill_step(params, tokens, memory=None, embeddings=None):
        return transformer.prefill(
            cfg, params, tokens=tokens, memory=memory, embeddings=embeddings,
            context_len=context_len)
    return prefill_step


def generate(cfg: ModelConfig, params, prompt: jax.Array, max_new: int,
             context_len: Optional[int] = None, temperature: float = 0.0,
             key: Optional[jax.Array] = None, memory=None):
    """Convenience loop for examples/tests: prefill + greedy decode.

    prompt [B, S] -> tokens [B, S + max_new].
    """
    B, S = prompt.shape
    context_len = context_len or (S + max_new)
    logits, state = transformer.prefill(cfg, params, tokens=prompt,
                                        memory=memory,
                                        context_len=context_len)
    last = sample_from_logits(logits[:, -1:], key, temperature)
    step = jax.jit(make_serve_step(cfg, temperature))
    out = [prompt, last]
    tok = last
    for i in range(max_new - 1):
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        tok, state = step(params, state, tok, jnp.int32(S + i), sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
