"""Zero-downtime weight rollout for the serve fabric.

The fabric (Registry + Routers + EngineServers) treats its N replicas as
one immutable deployment; this module adds the model lifecycle on top:
rolling the fleet from version A to version B **one replica at a time**,
with health gates and instant rollback, while clients keep getting
answers. The state machine per replica:

    drain  — ``Registry.set_draining(name, True)``: the replica stays
             registered and heartbeating but routers stop picking it;
             its in-flight requests finish on it, new ones go to
             siblings. Capacity dips to N−1 dispatchable, never lower.
    swap   — ``EngineServer.load_version(v)``: weights restore from the
             :class:`~repro.ckpt.checkpoint.ModelStore` and install
             between decode windows (shape-identical, so the compiled
             ladder stays warm — see ``ServeEngine.swap_params``).
    probe  — post-swap health gate: the replica must answer ``health()``
             healthy *and* report the new version. Failing the gate is
             grounds for fleet-wide rollback, not a shrug.
    canary — after the FIRST replica swaps, the routers pin a traffic
             fraction to the new version (``Router.set_canary``) and the
             controller compares the per-version latency/error rows.
             Regression past threshold → rollback. Pass → promote: roll
             the remaining replicas the same drain/swap/probe way.

**No separate source of truth.** The controller keeps no durable state:
which replica serves which version lives in the Registry's version table
(each replica's heartbeat load report carries its loaded version), and
``rollout()`` re-reads that table as it goes. A controller that dies
mid-rollout and restarts simply calls ``rollout()`` again: replicas
already at the target are skipped, half-done work is finished, and a
halted rollout's ``rollback()`` re-derives exactly which replicas to
re-pin. A replica that dies mid-drain is detected (its load probe fails
or it falls out of the table), reported to the registry, and skipped —
its in-flight requests fail over through the router like any crash.

Rollback is *instant* by design: no drain on the way back. The engine
still installs the old weights between decode windows, so requests in
flight on a bad canary complete — a few tokens may be sampled under
mixed versions, which is the accepted cost of getting a regressing model
out of the serving path in one RPC per replica.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Optional, Sequence

from repro.core import courier, telemetry


def _vkey(version: Any) -> Optional[str]:
    return None if version is None else str(version)


class RolloutController:
    """Drives drain → swap → probe → canary → promote/rollback.

    ``registry`` and ``routers`` are duck-typed (courier clients/handles
    or in-process objects). ``client_factory`` builds a client for a
    replica endpoint (defaults to :func:`repro.core.courier.client_for`).

    Canary gate: after the first replica swaps, ``canary_fraction`` of
    traffic is pinned to the new version until ``canary_requests``
    completions (or ``canary_timeout_s``); the new version fails the gate
    when its p50 latency exceeds ``regression_ratio`` × the old
    version's, or its error rate exceeds the old one's by more than
    ``error_rate_margin``. With no routers (or ``canary_requests=0``)
    the canary phase is skipped — a plain health-gated rolling restart.
    """

    def __init__(self, registry: Any, routers: Sequence[Any] = (), *,
                 client_factory: Optional[Callable[[str], Any]] = None,
                 drain_timeout_s: float = 30.0,
                 poll_s: float = 0.01,
                 canary_fraction: float = 0.25,
                 canary_requests: int = 8,
                 canary_timeout_s: float = 30.0,
                 regression_ratio: float = 2.0,
                 error_rate_margin: float = 0.05):
        self._registry = registry
        self._routers = list(routers)
        self._client_factory = client_factory or courier.client_for
        self._drain_timeout = drain_timeout_s
        self._poll = poll_s
        self._canary_fraction = canary_fraction
        self._canary_requests = canary_requests
        self._canary_timeout = canary_timeout_s
        self._ratio = regression_ratio
        self._err_margin = error_rate_margin

    # -- registry views ------------------------------------------------------
    def _table(self) -> dict:
        return self._registry.version_table()

    def _baseline_version(self, table: dict, target: Any) -> Optional[Any]:
        """The version the fleet is rolling *from*: the most common
        non-target version in the live table (re-derived, so a restarted
        controller mid-rollout still rolls back to the right place)."""
        counts: dict[str, tuple[int, Any]] = {}
        for info in table.values():
            v = info.get("version")
            if v is None or _vkey(v) == _vkey(target):
                continue
            key = _vkey(v)
            n, _ = counts.get(key, (0, v))
            counts[key] = (n + 1, v)
        if not counts:
            return None
        return max(counts.values())[1]

    # -- single-replica state machine ----------------------------------------
    def _undrain(self, name: str) -> None:
        try:
            self._registry.set_draining(name, False)
        except Exception:  # noqa: BLE001 - registry hiccup: TTL-safe
            pass

    def _probe_dead(self, name: str, client: Any) -> bool:
        """A swap or health RPC just failed: is the replica DEAD (crashed
        — report it and skip) or alive-but-refusing (bad version — roll
        back)? Dead shows as the name already gone from the table, the
        health probe raising, or health reporting a non-ok status (an
        engine that was killed under its still-responding server). A
        genuinely alive replica answers ok on the spot."""
        if name not in self._table():
            return True
        try:
            healthy = client.health().get("status") == "ok"
        except BaseException:  # noqa: BLE001 - transport/replica died
            healthy = False
        if healthy:
            return False
        try:
            self._registry.report_failure(name)
        except Exception:  # noqa: BLE001 - registry hiccup: TTL-safe
            pass
        return True

    def _wait_drained(self, name: str, client: Any) -> str:
        """Until the replica has no queued or in-flight work. Returns
        ``drained`` | ``dead`` | ``timeout``. A replica killed mid-drain
        is the expected chaos case: detect it, evict it, move on."""
        deadline = time.monotonic() + self._drain_timeout
        while time.monotonic() < deadline:
            if name not in self._table():       # evicted (TTL or report)
                return "dead"
            try:
                load = client.load()
            except BaseException:  # noqa: BLE001 - transport/replica died
                try:
                    self._registry.report_failure(name)
                except Exception:  # noqa: BLE001
                    pass
                return "dead"
            slots = int(load.get("num_slots", 0))
            if (int(load.get("free_slots", 0)) >= slots
                    and int(load.get("queue_depth", 0)) == 0):
                return "drained"
            time.sleep(self._poll)
        return "timeout"

    def _roll_one(self, name: str, endpoint: str, target: Any) -> str:
        """drain → swap → probe one replica. Returns ``swapped`` |
        ``dead`` | ``drain_timeout`` | ``swap_failed`` | ``unhealthy``."""
        try:
            client = self._client_factory(endpoint)
        except BaseException:  # noqa: BLE001 - unreachable endpoint
            return "dead"
        self._registry.set_draining(name, True)
        telemetry.record_event("drain", cause=f"rollout to v{target}",
                               replica=name)
        print(f"rollout: draining {name}", flush=True)
        state = self._wait_drained(name, client)
        if state == "dead":
            print(f"rollout: {name} died mid-drain; skipping", flush=True)
            return "dead"
        if state == "timeout":
            self._undrain(name)
            return "drain_timeout"
        try:
            client.load_version(target)
        except BaseException as exc:  # noqa: BLE001 - bad version/transport
            if self._probe_dead(name, client):
                print(f"rollout: {name} died before swap; skipping",
                      flush=True)
                return "dead"
            print(f"rollout: {name} swap to v{target} failed ({exc!r})",
                  flush=True)
            return "swap_failed"
        try:
            health = client.health()
        except BaseException:  # noqa: BLE001
            return "dead" if self._probe_dead(name, client) else "unhealthy"
        if (health.get("status") != "ok"
                or _vkey(health.get("version")) != _vkey(target)):
            return "dead" if self._probe_dead(name, client) else "unhealthy"
        self._undrain(name)
        telemetry.record_event("swap", cause=f"now serving v{target}",
                               replica=name)
        print(f"rollout: {name} now serving v{target}", flush=True)
        return "swapped"

    # -- canary gate ---------------------------------------------------------
    def _per_version_rows(self) -> dict:
        merged: dict[str, dict] = {}
        for router in self._routers:
            try:
                rows = router.stats().get("per_version", {})
            except BaseException:  # noqa: BLE001 - router mid-restart
                continue
            for key, row in rows.items():
                agg = merged.setdefault(key, {"completed": 0, "errors": 0,
                                              "lat_us_sum": 0.0})
                agg["completed"] += row["completed"]
                agg["errors"] += row["errors"]
                # Completion-weighted p50 average across routers.
                agg["lat_us_sum"] += row["p50_lat_us"] * row["completed"]
        for agg in merged.values():
            agg["p50_lat_us"] = agg["lat_us_sum"] / (agg["completed"] or 1)
        return merged

    def _set_canary(self, version: Optional[Any], fraction: float) -> None:
        for router in self._routers:
            try:
                router.set_canary(version, fraction)
            except BaseException:  # noqa: BLE001
                pass

    def _canary_verdict(self, target: Any, baseline: Any) -> dict:
        """Pin traffic to the canary, wait for samples, compare rows."""
        tkey, bkey = _vkey(target), _vkey(baseline)
        start = self._per_version_rows().get(tkey, {})
        already = start.get("completed", 0)
        self._set_canary(target, self._canary_fraction)
        deadline = time.monotonic() + self._canary_timeout
        rows: dict = {}
        while time.monotonic() < deadline:
            rows = self._per_version_rows()
            done = rows.get(tkey, {}).get("completed", 0) - already
            if done >= self._canary_requests:
                break
            time.sleep(self._poll)
        self._set_canary(None, 0.0)
        canary = rows.get(tkey, {"completed": 0, "errors": 0,
                                 "p50_lat_us": 0.0})
        base = rows.get(bkey) if bkey is not None else None
        verdict = {"canary": {k: canary.get(k) for k in
                              ("completed", "errors", "p50_lat_us")},
                   "baseline": None if base is None else
                               {k: base.get(k) for k in
                                ("completed", "errors", "p50_lat_us")}}
        samples = canary["completed"] - already
        if samples < self._canary_requests:
            # Not enough canary traffic to judge (idle fabric): health
            # probes already passed — promote, but say so.
            verdict.update(ok=True, reason=f"short sample ({samples})")
            return verdict
        if base is not None and base["completed"] > 0:
            c_rate = canary["errors"] / max(canary["completed"], 1)
            b_rate = base["errors"] / base["completed"]
            if c_rate > b_rate + self._err_margin:
                verdict.update(ok=False,
                               reason=f"error rate {c_rate:.3f} vs "
                                      f"{b_rate:.3f}")
                return verdict
            if (base["p50_lat_us"] > 0
                    and canary["p50_lat_us"]
                        > self._ratio * base["p50_lat_us"]):
                verdict.update(
                    ok=False,
                    reason=f"p50 {canary['p50_lat_us']:.0f}us > "
                           f"{self._ratio:g}x baseline "
                           f"{base['p50_lat_us']:.0f}us")
                return verdict
        verdict.update(ok=True, reason="within thresholds")
        return verdict

    # -- fleet operations ----------------------------------------------------
    def rollback(self, old: Any, target: Any,
                 extra: Sequence[str] = ()) -> dict:
        """Re-pin every replica the table says is at ``target`` back to
        ``old`` — instant (no drain), idempotent, re-derivable: safe to
        call from a restarted controller that only knows the two
        versions. ``extra`` names replicas known-swapped this run whose
        heartbeat may not have carried the new version yet (the table
        lags one beat period)."""
        self._set_canary(None, 0.0)
        telemetry.record_event("rollback", cause=f"re-pinning fleet to v{old}",
                               target=str(target))
        outcomes: dict[str, str] = {}
        for name, info in sorted(self._table().items()):
            if (_vkey(info.get("version")) != _vkey(target)
                    and name not in extra):
                self._undrain(name)     # clear any leftover drain marks
                continue
            try:
                client = self._client_factory(info["endpoint"])
                client.load_version(old)
                outcomes[name] = "restored"
            except BaseException as exc:  # noqa: BLE001 - dead replica
                outcomes[name] = f"failed ({exc!r})"
            self._undrain(name)
        print(f"rollout: rolled back to v{old} ({outcomes})", flush=True)
        return outcomes

    def rollout(self, target: Any) -> dict:
        """Roll the live fleet to ``target``, one replica at a time.

        Returns a summary dict with ``status`` ``promoted`` (every live
        replica serves ``target``) or ``rolled_back`` (a health gate or
        the canary comparison failed; every live replica was re-pinned to
        the version the fleet was on). Restart-safe: all progress state
        is re-read from the registry's version table, so calling this
        again after a controller crash resumes where it left off.
        """
        t0 = time.monotonic()
        table = self._table()
        if not table:
            return {"status": "no_replicas", "target": target}
        baseline = self._baseline_version(table, target)
        outcomes: dict[str, str] = {}
        canary_verdict: Optional[dict] = None
        canary_pending = bool(self._routers) and self._canary_requests > 0
        while True:
            # Fresh view every iteration: replicas already at target
            # (including ones a previous controller incarnation rolled)
            # are skipped; new arrivals at the old version are picked up.
            # ``outcomes`` only guards against *this run* re-touching a
            # replica whose heartbeat hasn't carried the new version yet
            # (the table lags one beat) or one that already died on us.
            table = self._table()
            pending = [(name, info) for name, info in sorted(table.items())
                       if _vkey(info.get("version")) != _vkey(target)
                       and outcomes.get(name) not in ("swapped", "dead")]
            if not pending:
                break
            name, info = pending[0]
            outcome = self._roll_one(name, info["endpoint"], target)
            outcomes[name] = outcome
            if outcome == "dead":
                continue
            if outcome != "swapped":
                if baseline is not None:
                    self.rollback(baseline, target,
                                  extra=[n for n, o in outcomes.items()
                                         if o == "swapped"])
                return {"status": "rolled_back", "target": target,
                        "baseline": baseline, "replicas": outcomes,
                        "canary": canary_verdict,
                        "reason": f"{name}: {outcome}",
                        "duration_s": time.monotonic() - t0}
            if canary_pending:
                canary_pending = False
                # Only a comparison when someone still serves baseline.
                if any(_vkey(i.get("version")) == _vkey(baseline)
                       for i in self._table().values()):
                    canary_verdict = self._canary_verdict(target, baseline)
                    if not canary_verdict["ok"]:
                        self.rollback(baseline, target,
                                      extra=[n for n, o in outcomes.items()
                                             if o == "swapped"])
                        return {"status": "rolled_back", "target": target,
                                "baseline": baseline, "replicas": outcomes,
                                "canary": canary_verdict,
                                "reason": "canary: "
                                          + canary_verdict["reason"],
                                "duration_s": time.monotonic() - t0}
        self._set_canary(None, 0.0)
        return {"status": "promoted", "target": target,
                "baseline": baseline, "replicas": outcomes,
                "canary": canary_verdict,
                "duration_s": time.monotonic() - t0}
