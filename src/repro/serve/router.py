"""Serve-fabric router: least-loaded dispatch across engine replicas.

The control plane over the PR 1-3 data plane: replicas register with a
:class:`repro.core.discovery.Registry` and heartbeat a load report (free
KV slots, queue depth, EWMA us/token); a :class:`Router` admits requests
and forwards each one to the least-loaded healthy replica over the
existing courier ``futures`` pipeline. The program graph stays static —
``clients -> router -> registry`` handles — while the *membership* under
the router moves at runtime:

  * **Discovery**: a background thread polls ``registry.lookup()`` every
    ``refresh_s``; new replicas get a courier client, evicted ones are
    dropped (their in-flight requests fail over first). Every poll also
    refreshes the load reports — membership generation alone can't
    short-circuit it, because heartbeats update loads without bumping
    the generation.
  * **Routing**: per-request score = local in-flight count (this
    router's own dispatches, exact) + the replica's last-reported queue
    depth − its reported free slots; the freshest signal (our own
    in-flight deltas) dominates between heartbeats, ties break
    round-robin. Requests never pin to a replica: two requests from one
    client may land on two engines. A replica serving a *paged* engine
    reports free pages and expected pages-per-request alongside free
    slots, and the score caps admission headroom at
    ``free_pages / pages_per_request`` — a replica with idle rows but a
    drained page pool stops looking attractive.
  * **Coalesced dispatch** (``coalesce=True``, the default): ``submit``
    does not send its own RPC. It parks the call on a pending queue and
    a single dispatcher thread drains the queue, packing every call
    bound for the same replica into ONE courier ``batch_call`` frame
    and fanning the per-call results back out to the callers' futures.
    The flush policy is adaptive, not timed: an idle dispatcher flushes
    a lone arrival immediately (no added latency), and while it is busy
    sending one frame the next arrivals pile up behind it and leave as
    one frame — under load, frames form exactly as fast as the
    transport can carry them. Per-frame cost (serialize + send) is paid
    once per frame instead of once per call; failure semantics are
    unchanged because a frame-level transport error fans out to every
    caller and feeds the same failover classification as a per-call
    error.
  * **Failover**: a dispatch that dies with a *replica* error (transport
    failure, stopped engine) is retried on a sibling — bounded by
    ``max_retries`` — and the failed replica is evicted from the
    registry (``report_failure``) so other routers stop picking it too.
    A *request* error (bad prompt: ``ValueError``/``TypeError``) is
    returned to the caller unretried: resending a poisoned request N
    times is how fabrics melt down. When the failover leaves no healthy
    replica at all, the caller gets ``Overloaded`` (retry-later) rather
    than the dead replica's error — a stalled-but-live replica
    re-registers on its next heartbeat, so the condition is transient by
    construction.
  * **Backpressure**: when every healthy replica is at its admission
    budget (in-flight ≥ ``2 * num_slots``: a full pool plus an equally
    deep queue), ``submit`` fails fast with the typed
    :class:`Overloaded` instead of queueing unboundedly. Callers treat
    it as a retry-later signal (see :func:`is_overloaded`, which unwraps
    the courier ``RemoteError`` envelope).

  * **Rollout support**: a replica the registry marks *draining*
    (``Registry.set_draining`` — registered and heartbeating, but being
    taken out for a weight swap) stays in the table with its transport
    open while new dispatches go to siblings, and it does not count
    toward the Overloaded budget check. With ``set_canary(version,
    fraction)`` the router pins that fraction of requests to replicas
    reporting the canary model version (and steers the rest away from
    it), and keeps **per-version** latency/error rows in ``stats()`` so
    a RolloutController can compare old-vs-new percentiles before
    promoting fleet-wide. Version pinning is a preference, not a wall:
    if no replica of the wanted version is admissible, the request runs
    on whatever is — a canary must never fail requests.

The router is an ordinary ``CourierNode`` service: ``submit`` blocks its
RPC handler thread for one reply, so the courier server's handler pool is
the router's concurrency. Several routers can front the same registry;
each keeps its own in-flight counters (the heartbeat load reports carry
the cross-router signal).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent import futures as cf
from typing import Any, Callable, Optional

from repro.core import courier, telemetry
from repro.core.courier.serialization import RemoteError
from repro.core.nodes.base import get_current_context


class Overloaded(RuntimeError):
    """Every healthy replica is at its admission budget. Typed so callers
    can tell "back off and retry" from a real failure."""


def unwrap_remote(exc: BaseException) -> BaseException:
    """Peel courier ``RemoteError`` envelopes down to the service's own
    exception (cross-transport: inproc raises originals, gRPC/shm wrap)."""
    seen: set[int] = set()
    while (isinstance(exc, RemoteError) and exc.__cause__ is not None
           and id(exc) not in seen):
        seen.add(id(exc))
        exc = exc.__cause__
    return exc


def is_overloaded(exc: BaseException) -> bool:
    return isinstance(unwrap_remote(exc), Overloaded)


def _is_request_error(exc: BaseException) -> bool:
    """Errors the *request* caused — retrying them on a sibling would just
    fail N times (and poison N engines' admission paths)."""
    return isinstance(unwrap_remote(exc), (ValueError, TypeError))


def _is_timeout(exc: BaseException) -> bool:
    """Timeouts — local or raised server-side and shipped back wrapped —
    mean slow, not dead: never grounds for evicting the replica."""
    return isinstance(unwrap_remote(exc), (TimeoutError, cf.TimeoutError))


def decorrelated_backoff(prev_s: float, rng, base_s: float = 0.005,
                         cap_s: float = 0.5) -> float:
    """Next sleep for an Overloaded retry: decorrelated jitter,
    ``min(cap, U(base, 3*prev))``. When a drain momentarily drops capacity
    by one replica, every client sees Overloaded at once; a fixed (or
    deterministic-exponential) schedule has them all resubmit on the same
    tick and re-stampede a fabric that just told them it is full. Jitter
    spreads the retry wave; the 3x term still grows the mean under
    sustained overload. ``rng`` is any object with ``uniform(a, b)``."""
    return min(cap_s, rng.uniform(base_s, max(prev_s, base_s) * 3.0))


@dataclasses.dataclass
class _Replica:
    name: str
    endpoint: str
    client: Any
    load: dict
    inflight: int = 0
    dispatched: int = 0
    # Removed from the routing table while requests are still in flight
    # (TTL eviction of a maybe-just-stalled replica): no new dispatches,
    # but the transport stays open until the last one resolves.
    draining: bool = False
    # Registry-directed drain (rollout taking the replica out for a weight
    # swap): still registered and heartbeating, transport open, but not a
    # dispatch candidate until the mark clears.
    undispatchable: bool = False

    @property
    def version(self) -> Optional[str]:
        v = self.load.get("version")
        return None if v is None else str(v)

    def budget(self, queue_slack: Optional[int]) -> int:
        slots = int(self.load.get("num_slots", 8)) or 8
        slack = slots if queue_slack is None else queue_slack
        return slots + slack

    def score(self) -> float:
        # Local in-flight is exact and fresh; the reported queue/free pair
        # is at most one heartbeat old and carries other routers' traffic.
        # A paged engine's row count overstates its headroom when the page
        # pool is the binding constraint: cap "free" at the number of
        # expected-size requests the remaining pages can hold.
        free = float(self.load.get("free_slots", 0))
        if "free_pages" in self.load:
            ppr = max(float(self.load.get("pages_per_request_ewma") or 0.0),
                      1.0)
            free = min(free, float(self.load.get("free_pages", 0)) / ppr)
        return (self.inflight
                + float(self.load.get("queue_depth", 0))
                - free)


class Router:
    """Admission front for a replicated serve fabric.

    ``registry`` is a handle/client for (or direct reference to) a
    :class:`~repro.core.discovery.Registry`. ``client_factory`` builds a
    courier client from an endpoint (defaults to
    :func:`repro.core.courier.client_for`; tests inject fakes).
    """

    def __init__(self, registry: Any, *, refresh_s: float = 0.25,
                 max_retries: int = 2, queue_slack: Optional[int] = None,
                 startup_wait_s: float = 15.0,
                 request_timeout_s: float = 120.0,
                 coalesce: bool = True,
                 client_factory: Optional[Callable[[str], Any]] = None):
        self._registry = registry
        self._refresh_s = refresh_s
        self._max_retries = max_retries
        self._queue_slack = queue_slack
        self._startup_wait = startup_wait_s
        self._timeout = request_timeout_s
        self._coalesce = coalesce
        self._client_factory = client_factory or courier.client_for

        self._lock = threading.Lock()
        self._node = telemetry.node_name()
        self._replicas: dict[str, _Replica] = {}
        self._draining: list[_Replica] = []
        self._generation = -1
        self._closed = threading.Event()
        self._ctx_stop = get_current_context().stop_event
        self._counters = dict(submitted=0, completed=0, retries=0,
                              failovers=0, overloaded=0, request_errors=0,
                              refreshes=0, dispatches=0, frames=0,
                              coalesced_calls=0, dispatch_us_sum=0.0)
        self._first_failover_done_s: Optional[float] = None
        # Canary routing: (version, fraction) plus a fractional
        # accumulator that meters out exactly `fraction` of requests to
        # the canary version, deterministically (no sampling noise in the
        # comparison rows). Per-version completion/latency/error rows use
        # the same namespacing idea as the Meter's per_source percentiles.
        self._canary: Optional[tuple[str, float]] = None
        self._canary_acc = 0.0
        self._per_version: dict[str, dict] = {}

        # Coalesced-dispatch state: (replica, call, caller future) triples
        # park here until the dispatcher thread drains them into
        # per-replica batch_call frames.
        self._pending_cv = threading.Condition(self._lock)
        self._pending_calls: collections.deque = collections.deque()
        self._dispatcher: Optional[threading.Thread] = None
        if coalesce:
            self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                                daemon=True,
                                                name="router-dispatch")
            self._dispatcher.start()

        self._refresh()                            # best-effort initial view
        self._thread = threading.Thread(target=self._refresh_loop,
                                        daemon=True, name="router-refresh")
        self._thread.start()

    # -- membership ----------------------------------------------------------
    def _refresh_loop(self) -> None:
        while not (self._closed.is_set() or self._ctx_stop.is_set()):
            self._closed.wait(self._refresh_s)
            if self._closed.is_set() or self._ctx_stop.is_set():
                return
            self._refresh()

    def _refresh(self) -> None:
        try:
            view = self._registry.lookup()
        except Exception:  # noqa: BLE001 - registry down: keep last view
            return
        live = {r["name"]: r for r in view["replicas"]}
        to_close, missing = [], []
        with self._lock:
            self._counters["refreshes"] += 1
            self._generation = view["generation"]
            for name in list(self._replicas):
                if name not in live:
                    rep = self._replicas.pop(name)
                    if rep.inflight > 0:
                        # TTL eviction may just mean stalled: closing the
                        # transport now would abort the in-flight requests
                        # of a replica that is still serving them. Stop
                        # dispatching; the last release closes it.
                        rep.draining = True
                        self._draining.append(rep)
                    else:
                        to_close.append(rep)
            for name, info in live.items():
                rep = self._replicas.get(name)
                if rep is None:
                    missing.append(info)
                else:
                    rep.load = dict(info["load"])
                    rep.undispatchable = bool(info.get("draining", False))
        # Client construction does connect I/O (shm rendezvous probe, gRPC
        # channel) — never under the dispatch lock.
        built = []
        for info in missing:
            try:
                built.append(_Replica(
                    name=info["name"], endpoint=info["endpoint"],
                    client=self._client_factory(info["endpoint"]),
                    load=dict(info["load"]),
                    undispatchable=bool(info.get("draining", False))))
            except Exception:  # noqa: BLE001 - endpoint unreachable
                continue
        with self._lock:
            for rep in built:
                if rep.name in self._replicas:   # lost a refresh race
                    to_close.append(rep)
                else:
                    self._replicas[rep.name] = rep
        for rep in to_close:
            self._close_client(rep)

    @staticmethod
    def _close_client(rep: _Replica) -> None:
        close = getattr(rep.client, "close", None)
        if callable(close):
            try:
                close()
            except Exception:  # noqa: BLE001 - already-dead transport
                pass

    def _drop_replica(self, rep: _Replica) -> None:
        """A dispatch observed ``rep`` failing: drop it locally and evict
        it registry-wide so siblings stop picking it too. A live replica
        re-registers on its next heartbeat.

        Dropped by *identity*, not name: if the failure came from an old
        (drained) incarnation while a recovered replica already
        re-registered under the same name, the fresh entry — and its
        in-flight requests — must survive the stale error."""
        superseded = False
        with self._lock:
            cur = self._replicas.get(rep.name)
            if cur is rep:
                self._replicas.pop(rep.name)
            else:
                superseded = cur is not None
            if rep.draining:
                if rep in self._draining:   # _release may have beaten us
                    self._draining.remove(rep)
                rep.draining = False        # this close is the final one
        self._close_client(rep)
        if superseded:
            return
        telemetry.record_event("replica_dropped",
                               cause="dispatch observed a replica error",
                               node=self._node, replica=rep.name)
        try:
            self._registry.report_failure(rep.name)
        except Exception:  # noqa: BLE001 - registry down: TTL will evict
            pass

    # -- canary routing ------------------------------------------------------
    def set_canary(self, version: Optional[Any],
                   fraction: float = 0.0) -> None:
        """Pin ``fraction`` of requests to replicas serving ``version``
        (and steer the remainder away from it, so the per-version rows
        compare clean populations). ``set_canary(None)`` clears."""
        with self._lock:
            if version is None or fraction <= 0:
                self._canary = None
            else:
                self._canary = (str(version), min(float(fraction), 1.0))
            self._canary_acc = 0.0

    def _want_version(self) -> tuple[Optional[str], Optional[str]]:
        """(want, avoid) version preference for one request under the
        current canary split. Caller holds the lock."""
        if self._canary is None:
            return None, None
        version, fraction = self._canary
        self._canary_acc += fraction
        if self._canary_acc >= 1.0:
            self._canary_acc -= 1.0
            return version, None
        return None, version

    def _version_row(self, version: Optional[str]) -> dict:
        """Per-version accounting row. Caller holds the lock."""
        key = version if version is not None else "unversioned"
        row = self._per_version.get(key)
        if row is None:
            row = {"completed": 0, "errors": 0, "lat_sum_s": 0.0,
                   "tokens": 0, "lats": collections.deque(maxlen=512)}
            self._per_version[key] = row
        return row

    # -- dispatch ------------------------------------------------------------
    def _pick(self, exclude: set[str]) -> Optional[_Replica]:
        """Least-loaded healthy replica under budget, or None. Raises
        Overloaded when replicas exist but every one is at budget.
        Registry-draining replicas are not candidates and do not count
        toward the budget check (a drain is planned capacity loss, not
        congestion)."""
        with self._lock:
            candidates = [r for name, r in self._replicas.items()
                          if name not in exclude and not r.undispatchable]
            if not candidates:
                return None
            admissible = [r for r in candidates
                          if r.inflight < r.budget(self._queue_slack)]
            if not admissible:
                self._counters["overloaded"] += 1
                telemetry.record_event(
                    "overloaded", cause="all replicas at admission budget",
                    node=self._node, replicas=len(candidates))
                raise Overloaded(
                    f"all {len(candidates)} replicas at admission budget "
                    f"(in-flight {[r.inflight for r in candidates]})")
            want, avoid = self._want_version()
            if want is not None:
                preferred = [r for r in admissible if r.version == want]
            elif avoid is not None:
                preferred = [r for r in admissible if r.version != avoid]
            else:
                preferred = admissible
            # Preference, not a wall: an empty preferred set (canary
            # draining, dead, or not up yet) falls back to anything
            # admissible rather than failing the request.
            if preferred:
                admissible = preferred
            # Ties go to the replica dispatched least: equal scores
            # round-robin instead of pinning to dict order.
            best = min(admissible, key=lambda r: (r.score(), r.dispatched))
            best.inflight += 1
            best.dispatched += 1
            return best

    def _release(self, rep: _Replica) -> None:
        with self._lock:
            rep.inflight -= 1
            drained = rep.draining and rep.inflight <= 0
            if drained:
                if rep in self._draining:   # close() may have beaten us
                    self._draining.remove(rep)
                rep.draining = False
        if drained:
            self._close_client(rep)

    # -- coalesced dispatch --------------------------------------------------
    def _enqueue(self, rep: _Replica, method: str, args: tuple,
                 kwargs: dict) -> cf.Future:
        """Park one call for the dispatcher; returns the caller's future.
        The dispatcher packs every call bound for the same replica that is
        pending at drain time into one ``batch_call`` frame.

        Trace propagation happens HERE, on the caller's handler thread —
        the dispatcher thread has no request context. The envelope's
        context is parented under a pre-minted ``dispatch`` span id, so
        engine-side spans nest under the dispatch that carried them; the
        span itself is recorded when the frame COMPLETES, covering
        send -> results-back (the replica-side spans nest inside it;
        the serialize+send share rides along as ``send_us``)."""
        fut: cf.Future = cf.Future()
        ctx = telemetry.current_context()
        sid = None
        if ctx is not None and ctx.sampled:
            sid = telemetry.new_span_id()
            kwargs = dict(kwargs)
            kwargs[telemetry.TRACE_KEY] = ctx.child(sid).to_wire()
        with self._pending_cv:
            self._pending_calls.append(
                (rep, (method, args, kwargs), fut, ctx, sid))
            self._pending_cv.notify()
        return fut

    def _dispatch_loop(self) -> None:
        while True:
            with self._pending_cv:
                while (not self._pending_calls
                       and not (self._closed.is_set()
                                or self._ctx_stop.is_set())):
                    self._pending_cv.wait(timeout=0.5)
                items = list(self._pending_calls)
                self._pending_calls.clear()
                stopping = self._closed.is_set() or self._ctx_stop.is_set()
            if stopping and not items:
                return
            # Group by replica identity: one frame per replica per drain.
            # Anything that arrived while the previous frames were being
            # serialized/sent leaves in the NEXT drain — that lag is the
            # whole coalescing window, so an idle router adds no latency.
            groups: dict[int, tuple[_Replica, list, list, list]] = {}
            for rep, call, fut, ctx, sid in items:
                key = id(rep)
                if key not in groups:
                    groups[key] = (rep, [], [], [])
                groups[key][1].append(call)
                groups[key][2].append(fut)
                groups[key][3].append((ctx, sid))
            for rep, calls, futs, traces in groups.values():
                self._send_frame(rep, calls, futs, traces)
            if stopping:
                return

    def _send_frame(self, rep: _Replica, calls: list, futs: list,
                    traces: Optional[list] = None) -> None:
        t0w = time.time()
        t0 = time.perf_counter()
        try:
            frame = rep.client.futures.batch_call(calls)
        except BaseException as exc:  # noqa: BLE001 - transport refused
            if traces:
                dur = time.perf_counter() - t0
                for ctx, sid in traces:
                    if sid is not None:
                        telemetry.record_span(
                            "dispatch", ctx, t0w, dur, span_id=sid,
                            node=self._node, replica=rep.name,
                            frame_calls=len(calls), error=repr(exc))
            for fut in futs:
                if not fut.set_running_or_notify_cancel():
                    continue
                fut.set_exception(exc)
            return
        # Counter accounting stays SEND cost (the router-added overhead
        # number the bench reports); the dispatch SPAN below covers the
        # full send -> results-back window so the trace timeline has no
        # hole while the frame is in flight on the replica.
        us = (time.perf_counter() - t0) * 1e6
        with self._lock:
            self._counters["frames"] += 1
            self._counters["dispatches"] += len(calls)
            self._counters["dispatch_us_sum"] += us
            if len(calls) > 1:
                self._counters["coalesced_calls"] += len(calls)

        def _fan(f: cf.Future) -> None:
            if traces:
                dur = time.perf_counter() - t0
                for ctx, sid in traces:
                    if sid is not None:
                        telemetry.record_span(
                            "dispatch", ctx, t0w, dur, span_id=sid,
                            node=self._node, replica=rep.name,
                            frame_calls=len(calls), send_us=us)
            try:
                results = f.result()
            except BaseException as exc:  # noqa: BLE001 - frame died whole
                results = [exc] * len(futs)
            for fut, res in zip(futs, results):
                if not fut.set_running_or_notify_cancel():
                    continue                    # caller already cancelled
                try:
                    if isinstance(res, BaseException):
                        fut.set_exception(res)
                    else:
                        fut.set_result(res)
                except cf.InvalidStateError:    # cancel raced the fan-out
                    pass

        frame.add_done_callback(_fan)

    def submit(self, prompt, max_new: Optional[int] = None):
        """Serve one request: returns the completed [S + n_generated]
        sequence, transparently failing over if the serving replica dies
        mid-decode. Raises :class:`Overloaded` when the fabric is full."""
        with self._lock:
            self._counters["submitted"] += 1
        t_req = time.monotonic()
        deadline = time.monotonic() + self._startup_wait
        tried: set[str] = set()
        attempts = 0
        failed_over = False
        last_exc: Optional[BaseException] = None
        # Trace context rides in on this RPC handler thread (activated by
        # the courier server); queue/dispatch spans are recorded per
        # attempt so a failover's extra hops stay visible in the timeline.
        tctx = telemetry.current_context()
        tracing = tctx is not None and tctx.sampled
        pick_t0w = pick_t0 = None
        while attempts <= self._max_retries:
            # Dispatch accounting starts per attempt: waits (startup
            # grace, a timed-out prior attempt) are not dispatch cost.
            if pick_t0 is None:
                pick_t0w, pick_t0 = time.time(), time.perf_counter()
            t0 = time.perf_counter()
            rep = self._pick(tried)
            if rep is None:
                if tried:
                    # Every replica left was tried and dropped: the fabric
                    # has no healthy replica *right now* — a retry-later
                    # condition (a stalled-but-live replica re-registers
                    # on its next beat), not this request's failure.
                    with self._lock:
                        self._counters["overloaded"] += 1
                    raise Overloaded(
                        f"no healthy replica left after {attempts} "
                        "attempts") from last_exc
                if time.monotonic() >= deadline:
                    with self._lock:
                        self._counters["overloaded"] += 1
                    raise Overloaded("no live replicas in the registry")
                # Launch is asynchronous: replicas may still be coming up.
                self._closed.wait(0.05)
                self._refresh()
                continue
            attempts += 1
            if tracing:
                # The queue/pick wait — including any waiting-for-replicas
                # iterations since the last dispatch attempt.
                telemetry.record_span(
                    "queue", tctx, pick_t0w,
                    time.perf_counter() - pick_t0, node=self._node,
                    replica=rep.name, attempt=attempts)
            pick_t0w = pick_t0 = None
            kwargs = {} if max_new is None else {"max_new": max_new}
            if self._coalesce:
                # Enqueue-only: the dispatcher thread owns the transport
                # send and the frame-level dispatch accounting. A dispatch
                # failure surfaces through the future and feeds the same
                # failover classification below.
                fut = self._enqueue(rep, "generate", (prompt,), kwargs)
            else:
                sid = None
                if tracing:
                    sid = telemetry.new_span_id()
                    kwargs = dict(kwargs)
                    kwargs[telemetry.TRACE_KEY] = \
                        tctx.child(sid).to_wire()
                d0w = time.time()
                try:
                    fut = rep.client.futures.generate(prompt, **kwargs)
                except BaseException as exc:  # noqa: BLE001 - dispatch failed
                    if sid is not None:
                        telemetry.record_span(
                            "dispatch", tctx, d0w,
                            time.perf_counter() - t0, span_id=sid,
                            node=self._node, replica=rep.name,
                            frame_calls=1, error=repr(exc))
                    self._release(rep)
                    last_exc = exc
                    tried.add(rep.name)
                    self._drop_replica(rep)
                    failed_over = True
                    with self._lock:
                        self._counters["retries"] += 1
                        self._counters["failovers"] += 1
                        self._version_row(rep.version)["errors"] += 1
                    continue
                if sid is not None:
                    # Span recorded at frame completion (send ->
                    # results-back), same window as the coalesced path;
                    # counters below keep the send-cost-only number.
                    send_us = (time.perf_counter() - t0) * 1e6

                    def _rec(f, _sid=sid, _d0w=d0w, _t0=t0, _rep=rep,
                             _send_us=send_us):
                        telemetry.record_span(
                            "dispatch", tctx, _d0w,
                            time.perf_counter() - _t0, span_id=_sid,
                            node=self._node, replica=_rep.name,
                            frame_calls=1, send_us=_send_us)
                    fut.add_done_callback(_rec)
                with self._lock:
                    self._counters["dispatches"] += 1
                    self._counters["frames"] += 1
                    self._counters["dispatch_us_sum"] += \
                        (time.perf_counter() - t0) * 1e6
            try:
                out = fut.result(timeout=self._timeout)
            except cf.TimeoutError as exc:
                # Slow is not dead: exclude the replica for this request
                # but let heartbeat TTL decide whether it leaves the set.
                fut.cancel()
                self._release(rep)
                last_exc = exc
                tried.add(rep.name)
                with self._lock:
                    self._counters["retries"] += 1
                continue
            except BaseException as exc:  # noqa: BLE001
                self._release(rep)
                if _is_request_error(exc):
                    with self._lock:
                        self._counters["request_errors"] += 1
                    # Deliver the service's own exception, not the batch
                    # envelope: per-call inproc dispatch raises originals,
                    # and coalesced frames must look the same to callers.
                    raise unwrap_remote(exc) from exc
                last_exc = exc
                tried.add(rep.name)
                if _is_timeout(exc):
                    # A *server-side* timeout arrives wrapped in the
                    # courier envelope: same policy as the local one
                    # above — exclude for this request, don't evict.
                    with self._lock:
                        self._counters["retries"] += 1
                    continue
                self._drop_replica(rep)
                failed_over = True
                with self._lock:
                    self._counters["retries"] += 1
                    self._counters["failovers"] += 1
                    self._version_row(rep.version)["errors"] += 1
                continue
            self._release(rep)
            r0w, r0 = time.time(), time.perf_counter()
            # Generated-token count, when the reply looks like a sequence
            # ([S + n_generated] vs the [S] prompt) — powers the
            # per-version us/token comparison the canary verdict reads.
            try:
                gen_tokens = max(len(out) - len(prompt), 1)
            except TypeError:
                gen_tokens = 1
            if tracing:
                # Router-side reply handling (fan-out + accounting); the
                # serialization half is recorded server-side on the
                # replica for non-inproc transports.
                telemetry.record_span("reply", tctx, r0w,
                                      time.perf_counter() - r0,
                                      node=self._node, replica=rep.name)
            with self._lock:
                self._counters["completed"] += 1
                row = self._version_row(rep.version)
                row["completed"] += 1
                lat = time.monotonic() - t_req
                row["lat_sum_s"] += lat
                row["tokens"] += gen_tokens
                row["lats"].append(lat)
                if failed_over and self._first_failover_done_s is None:
                    # When the first request that had to fail over lands:
                    # the fabric's observable recovery point after a kill.
                    self._first_failover_done_s = time.perf_counter()
            return out
        assert last_exc is not None
        raise last_exc

    # -- introspection -------------------------------------------------------
    def health(self) -> dict:
        with self._lock:
            return {"status": "ok", "replicas": len(self._replicas),
                    "dispatchable": sum(1 for r in self._replicas.values()
                                        if not r.undispatchable),
                    "generation": self._generation}

    def load(self) -> dict:
        with self._lock:
            return {"replicas": len(self._replicas),
                    "inflight": sum(r.inflight
                                    for r in self._replicas.values())}

    def stats(self) -> dict:
        with self._lock:
            s = dict(self._counters)
            s["generation"] = self._generation
            s["first_failover_done_s"] = self._first_failover_done_s
            s["replicas"] = {name: {"endpoint": r.endpoint,
                                    "inflight": r.inflight,
                                    "dispatched": r.dispatched,
                                    "version": r.version,
                                    "draining": r.undispatchable,
                                    "load": dict(r.load)}
                             for name, r in self._replicas.items()}
            s["per_version"] = {}
            for key, row in self._per_version.items():
                lats = sorted(row["lats"])
                n = len(lats)
                s["per_version"][key] = {
                    "completed": row["completed"],
                    "errors": row["errors"],
                    "mean_lat_us": 1e6 * row["lat_sum_s"]
                                   / (row["completed"] or 1),
                    "p50_lat_us": 1e6 * lats[n // 2] if n else 0.0,
                    "p95_lat_us": 1e6 * lats[min(n - 1, int(n * 0.95))]
                                  if n else 0.0,
                    "us_per_token": 1e6 * row["lat_sum_s"]
                                    / (row["tokens"] or 1),
                }
        # Per dispatch *attempt* — the sum accrues once per dispatch (one
        # frame may carry many dispatches, so coalescing shows up here as a
        # lower per-call mean), and a request that failed over contributes
        # each of its attempts.
        s["mean_dispatch_us"] = s.pop("dispatch_us_sum") / (s["dispatches"]
                                                            or 1)
        s["mean_calls_per_frame"] = s["dispatches"] / (s["frames"] or 1)
        return s

    def telemetry(self) -> dict:
        """Standard telemetry scrape: process metrics + drained spans and
        events, with the router's own ``stats()`` and each replica
        client's transport wire counters as the service payload."""
        transports: dict[str, dict] = {}
        with self._lock:
            reps = [(name, r.client) for name, r in self._replicas.items()]
        for name, client in reps:
            tr = getattr(client, "transport", None)
            stats = getattr(tr, "stats", None)
            if callable(stats):
                try:
                    transports[name] = stats()
                except Exception:  # noqa: BLE001 - closing transport
                    pass
        service = self.stats()
        service["transports"] = transports
        return telemetry.telemetry_snapshot(service=service)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        self._closed.set()
        with self._pending_cv:
            self._pending_cv.notify()
        if self._dispatcher is not None and self._dispatcher.is_alive():
            # The dispatcher drains (and sends) whatever is pending on its
            # way out, so in-flight submits still get replies.
            self._dispatcher.join(timeout=5)
        if self._thread.is_alive():
            self._thread.join(timeout=5)
        with self._lock:
            reps = list(self._replicas.values()) + self._draining
            for rep in reps:
                rep.draining = False    # a late _release must not re-close
            self._replicas.clear()
            self._draining.clear()
        for rep in reps:
            self._close_client(rep)

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
