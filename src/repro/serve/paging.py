"""Host-side bookkeeping for the paged KV pool: the refcounted
shared-prefix cache.

The device side of paging (the page pool, the per-row page table, the
copy-on-write scatter) lives in ``models.transformer`` /
``models.attention``; this module owns the *host* policy half: which
page-aligned prompt prefixes are cached, who holds references to a
physical page, and which cache entries give their pages back under pool
pressure.

Sharing model (copy-on-write by construction, not by trapping writes):

  * a cache entry keys the hash of a prompt's first ``c * page_size``
    tokens and holds the page-id chain materializing exactly those
    tokens' K/V. Only *fully prompt-covered* pages are ever registered
    (``c * page_size <= len(prompt)``), and decode writes for the owning
    row land at positions ``>= len(prompt)`` — so a registered page is
    never written again by anyone, and "copy on write" degenerates to
    "never write a shared page; write your own suffix pages".
  * refcounts: every row using a page holds one ref, and every cache
    entry whose chain contains the page holds one ref. A page returns to
    the free list exactly at refcount zero — a row retiring releases its
    refs immediately, but pages a cache entry still references stay
    resident for future hits.
  * eviction is LRU over cache entries, triggered by the engine only
    under pool pressure (an allocation that would otherwise fail):
    popping an entry drops its refs, freeing whichever of its pages no
    live row still uses.

A *hit* on admission means the request's leading page-list entries point
at the shared pages and its prefill starts at ``c * page_size`` (the
engine gathers the shared pages into a flat view and runs
``prefill_extend`` over the suffix only). The lookup caps the usable
prefix at ``(len(prompt) - 1) // page_size`` pages: the last prompt
token's logits must still be computed to sample the first generated
token, so at least one suffix token always prefills.
"""

from __future__ import annotations

import collections
import hashlib
from typing import Callable, Optional

import numpy as np


def _digest(prompt: np.ndarray, n_tokens: int) -> bytes:
    return hashlib.blake2b(np.ascontiguousarray(prompt[:n_tokens]).tobytes(),
                           digest_size=16).digest()


class PrefixCache:
    """Refcount-aware LRU map from page-aligned prompt-prefix hashes to
    physical page chains. Single-threaded (the engine's driver thread);
    ``stats()`` is safe to read from anywhere."""

    def __init__(self, page_size: int, max_entries: int = 512):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self._ps = page_size
        self._max = max_entries
        # key -> tuple of physical page ids (the chain holds one ref per
        # page; insertion order doubles as LRU order via move_to_end).
        self._entries: "collections.OrderedDict[bytes, tuple[int, ...]]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, prompt: np.ndarray) -> list[int]:
        """Longest cached page chain covering a strict prefix of
        ``prompt``. Returns the physical page ids ([] = miss). The caller
        owns taking a ref on each returned page."""
        c_max = (len(prompt) - 1) // self._ps
        for c in range(c_max, 0, -1):
            chain = self._entries.get(_digest(prompt, c * self._ps))
            if chain is not None:
                self._entries.move_to_end(_digest(prompt, c * self._ps))
                self.hits += 1
                return list(chain)
        self.misses += 1
        return []

    def insert(self, prompt: np.ndarray, row_pages: list[int],
               incref: Callable[[int], None],
               decref: Callable[[int], None]) -> None:
        """Register every page-aligned prefix of ``prompt`` that the
        row's pages fully cover. Chains for prefixes already cached are
        just touched (their pages are the shared ones the row reused)."""
        for c in range(1, len(prompt) // self._ps + 1):
            key = _digest(prompt, c * self._ps)
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            chain = tuple(row_pages[:c])
            for pid in chain:
                incref(pid)
            self._entries[key] = chain
            while len(self._entries) > self._max:
                self.evict_one(decref)

    def evict_one(self, decref: Callable[[int], None]) -> bool:
        """Drop the least-recently-used entry, releasing its page refs
        (pages only actually free once no live row uses them). Returns
        False when the cache is empty."""
        if not self._entries:
            return False
        _, chain = self._entries.popitem(last=False)
        for pid in chain:
            decref(pid)
        self.evictions += 1
        return True

    def clear(self, decref: Callable[[int], None]) -> None:
        while self._entries:
            _, chain = self._entries.popitem(last=False)
            for pid in chain:
                decref(pid)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0}
