"""Continuous-batching decode engine over a slotted KV cache.

The lockstep serving path (drain a queue, pad a batch, run
prefill+decode, reply per batch) makes every request wait for a batch
boundary and the whole batch wait for its slowest member. This engine
replaces that with *iteration-level* scheduling:

  * the KV cache is a fixed pool of ``num_slots`` rows
    (``transformer.init_decode_state`` with batch = num_slots);
  * a persistent decode loop steps ALL occupied slots together, each at
    its own absolute position (``decode_step`` with a per-row ``t``
    vector — ring-position masking keeps ragged rows correct);
  * decode runs in *fused windows*: sampling and the per-row
    feed-token/position updates live inside one jitted ``lax.scan``
    (``serve.decode.make_fused_serve_step``), so the feed tokens, the
    position vector, and the PRNG key stay device-resident and the host
    syncs one ``[num_slots, K]`` token block per window instead of one
    token per step. ``sync_every`` caps K (default 8); each window's K
    is picked from the power-of-two ladder by useful-tokens-per-cost
    (see ``step``), so draining tails shrink the window instead of
    burning speculative steps and at most log2(sync_every)+1
    executables exist. EOS / ``max_new``
    retirement is detected on the sync by slicing each row's block to
    its own stop point — bit-identical to syncing every step, because
    the scan body IS the single-step path;
  * ``decode_impl`` picks the attention leaf ("auto" | "dense" |
    "flash"): flash routes through the ``kernels.ops`` dispatcher — the
    one-HBM-pass flash-decode kernel on TPU, its jnp oracle as a native
    XLA executable elsewhere — with the ring-validity mask handed to the
    kernel as its precomputed ``valid`` mask;
  * arrivals are admitted into free slots *between* windows: the
    request is prefilled alone at its exact prompt length and its
    per-layer state is written into the free row with
    ``transformer.write_decode_slot`` (a donated dynamic-update, so
    admission never copies or perturbs in-flight rows);
  * with ``prefill_chunk`` set, a long prompt prefills in fixed-size
    chunks interleaved between decode windows (``prefill_extend``
    against a reserved slot's own B=1 state), so a long prompt never
    stalls in-flight decode for its full prefill. Chunking needs an
    attention-only stack; other stacks (and short prompts) fall back to
    the monolithic exact-length prefill. Admission order stays strict
    FCFS: while a chunked prefill is in progress, later arrivals wait;
  * a sequence retires the moment it finishes (EOS or its ``max_new``
    budget) and its slot is immediately reusable — nobody waits for a
    batch-mate;
  * replies stream back per request through ``concurrent.futures``.

Exact-length prefill (no padding) keeps admission correct for every
``decode_supported`` architecture, including the recurrent ones
(RG-LRU / Mamba) whose state a padded prefill would pollute; jit caches
one prefill executable per distinct prompt length. Requests that cannot
ever fit (prompt + max_new > context_len) fail their own future at
submit time — they never poison a step, and the queue keeps serving
everyone else. A full pool queues requests (FCFS) instead of erroring.

MoE caveat: expert routing under a capacity factor couples rows through
the shared capacity budget, so MoE decode in a shared pool is not
bit-identical to serving the same request alone (dense / recurrent
stacks are).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import queue
import threading
import time
from concurrent import futures as cf
from typing import Any, Optional

import numpy as np

from repro.models.config import ModelConfig

_CHUNKABLE_KINDS = {"attn", "swa", "local"}


def _pow2ceil(n: int) -> int:
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class _Request:
    prompt: np.ndarray            # [S] int32, detached copy
    max_new: int
    future: cf.Future
    submitted: float


@dataclasses.dataclass
class _Slot:
    request: _Request
    t: int                        # absolute position of the next token fed
    generated: list


@dataclasses.dataclass
class _PendingPrefill:
    """A chunked prefill in flight: the request holds its reserved slot
    while its prompt streams through ``prefill_extend`` one chunk per
    engine step, against its own B=1 state."""
    request: _Request
    slot: int
    state: Any                    # B=1 decode state (chunk-extended)
    consumed: int                 # prompt tokens already prefilled


class ServeEngine:
    """Continuous-batching serve engine.

    ``submit()`` is thread-safe and returns a ``concurrent.futures.Future``
    resolving to the full sequence (prompt + generated tokens, int32).
    Drive the engine either with ``start()`` (daemon decode loop — the
    serving deployment) or by calling ``step()`` directly from one thread
    (deterministic, used by tests and benchmarks).
    """

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 8,
                 context_len: int = 64, max_new: int = 16,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0, sync_every: int = 8,
                 top_k: Optional[int] = None, decode_impl: str = "auto",
                 prefill_chunk: Optional[int] = None):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer
        from repro.serve import decode as serve_lib

        if not cfg.decode_supported:
            raise ValueError(f"{cfg.name} has no autoregressive decode step")
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if decode_impl not in ("auto", "dense", "flash"):
            raise ValueError(f"decode_impl must be auto|dense|flash, "
                             f"got {decode_impl!r}")
        self._cfg = cfg
        self._params = params
        self._ns = num_slots
        self._L = context_len
        self._max_new = max_new
        self._eos = eos_id
        self._temp = temperature
        self._top_k = top_k
        self._impl = decode_impl
        self._sync = sync_every
        self._key = jax.random.key(seed) if temperature else None

        kinds = set(cfg.pattern) | set(cfg.remainder)
        self._chunk = prefill_chunk
        self._can_chunk = (prefill_chunk is not None
                           and kinds <= _CHUNKABLE_KINDS
                           and not cfg.conv_pos)
        if prefill_chunk is not None:
            ring = min((min(context_len, cfg.window or context_len)
                        if k in ("swa", "local") else context_len)
                       for k in kinds)
            if not 1 <= prefill_chunk <= ring:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be in [1, "
                    f"{ring}] (the smallest cache ring) — a larger chunk "
                    "would overwrite slots its own queries still attend to")

        self._state = transformer.init_decode_state(cfg, num_slots,
                                                    context_len)
        self._slots: list[Optional[_Slot]] = [None] * num_slots
        self._free: list[int] = list(range(num_slots - 1, -1, -1))
        # Device-resident hot state: the feed tokens and per-row positions
        # live on device between syncs (rebuilding them from host numpy
        # every step was a measurable per-step tax), and the fused window
        # threads them through donated buffers.
        self._tokens_dev = jnp.zeros((num_slots, 1), jnp.int32)
        self._t_dev = jnp.zeros((num_slots,), jnp.int32)

        # Fused-window executables, shared across engine instances via the
        # lru cache in serve.decode (keyed on every static knob, attn_impl
        # included — the kernel-vs-dense choice is baked at trace time).
        self._fused = functools.partial(
            serve_lib.cached_fused_step, cfg, temperature=temperature,
            top_k=top_k, attn_impl=decode_impl)
        self._sampler = jax.jit(serve_lib.make_sampler(temperature, top_k))

        def _prefill_fn(params, tokens, key=None):
            logits, state = transformer.prefill(cfg, params, tokens=tokens,
                                                context_len=context_len)
            nxt = serve_lib.make_sampler(temperature, top_k)(
                logits[:, -1:], key)
            return nxt, state

        # One executable per distinct prompt length (jit's shape cache).
        self._prefill = jax.jit(_prefill_fn)
        self._extend = jax.jit(
            functools.partial(transformer.prefill_extend, cfg),
            donate_argnums=(1,))
        self._write = jax.jit(
            functools.partial(transformer.write_decode_slot, cfg),
            donate_argnums=(0,))

        def _row_write_fn(tokens, t, i, tok, tval):
            return tokens.at[i, 0].set(tok), t.at[i].set(tval)

        self._row_write = jax.jit(_row_write_fn, donate_argnums=(0, 1))

        self._queue: queue.Queue[_Request] = queue.Queue()
        self._ready: collections.deque[_Request] = collections.deque()
        self._pending: Optional[_PendingPrefill] = None
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._lock = threading.Lock()                       # stats + lifecycle
        self._counters = dict(submitted=0, admitted=0, retired=0, failed=0,
                              steps=0, decode_tokens=0, generated_tokens=0,
                              occupancy_sum=0, peak_occupancy=0,
                              host_syncs=0)
        # EWMA decode-step microseconds per token: the routing signal a
        # load balancer uses to weigh this engine against its siblings.
        self._ewma_us_tok = 0.0

    # -- client side ---------------------------------------------------------
    def submit(self, prompt, max_new: Optional[int] = None) -> cf.Future:
        """Enqueue one request; resolves to [S + n_generated] int32.

        The prompt is copied (a transport-owned zero-copy view is safe to
        hand in; its lease is released as soon as submit returns). A
        request that cannot fit the slot ring fails its own future here —
        per-request delivery, no effect on its neighbours.
        """
        fut: cf.Future = cf.Future()
        prompt = np.asarray(prompt, np.int32).reshape(-1).copy()
        mn = self._max_new if max_new is None else int(max_new)
        if prompt.size == 0:
            fut.set_exception(ValueError("empty prompt"))
            return fut
        if prompt.size + mn > self._L:
            fut.set_exception(ValueError(
                f"prompt ({prompt.size}) + max_new ({mn}) exceeds the "
                f"engine's context_len ({self._L})"))
            return fut
        with self._lock:
            # The put happens under the same lock stop() takes before
            # draining, so a request can never slip into the queue after
            # the drain and hang its caller.
            if self._closed:
                fut.set_exception(RuntimeError("engine stopped"))
                return fut
            self._counters["submitted"] += 1
            self._queue.put(_Request(prompt, mn, fut, time.monotonic()))
        self._wake.set()
        return fut

    # -- engine side ---------------------------------------------------------
    def _activate(self, req: _Request, i: int, first: int) -> None:
        """Mark slot ``i`` live: host bookkeeping + the device-resident
        feed-token/position rows (one donated row write, no full-array
        host->device rebuild)."""
        import jax.numpy as jnp
        self._slots[i] = _Slot(request=req, t=len(req.prompt),
                               generated=[first])
        self._tokens_dev, self._t_dev = self._row_write(
            self._tokens_dev, self._t_dev, jnp.int32(i), jnp.int32(first),
            jnp.int32(len(req.prompt)))
        with self._lock:
            self._counters["admitted"] += 1
            self._counters["host_syncs"] += 1   # the first-token pull
        if (self._eos is not None and first == self._eos) \
                or req.max_new <= 1:
            self._retire(i)

    def _admit(self) -> None:
        """Move queued requests into free slots: exact-length prefill, then
        write the fresh per-layer state into the slot's cache row. Long
        prompts (with ``prefill_chunk`` on an attention-only stack) are
        parked as a _PendingPrefill instead and stream through
        ``_advance_chunk`` one chunk per step; admission order stays
        strict FCFS, so later arrivals wait behind an in-flight chunked
        prefill rather than jumping it."""
        import jax.numpy as jnp
        while True:
            try:
                self._ready.append(self._queue.get_nowait())
            except queue.Empty:
                break
        while self._free and self._ready:
            req = self._ready[0]
            chunked = self._can_chunk and len(req.prompt) > self._chunk
            if chunked and self._pending is not None:
                return                          # FCFS: wait for the pending
            self._ready.popleft()
            if not req.future.set_running_or_notify_cancel():
                continue                                    # cancelled
            i = self._free.pop()
            if chunked:
                from repro.models import transformer
                self._pending = _PendingPrefill(
                    request=req, slot=i,
                    state=transformer.init_decode_state(self._cfg, 1,
                                                        self._L),
                    consumed=0)
                continue
            try:
                key = self._split_key()
                nxt, slot_state = self._prefill(
                    self._params, jnp.asarray(req.prompt[None]), key)
                self._state = self._write(self._state, slot_state,
                                          jnp.int32(i))
                first = int(np.asarray(nxt)[0, 0])
            except Exception as exc:                        # noqa: BLE001
                # Per-request failure delivery: the slot goes straight back
                # and the step proceeds for everyone else.
                self._free.append(i)
                with self._lock:
                    self._counters["failed"] += 1
                req.future.set_exception(exc)
                continue
            self._activate(req, i, first)

    def _advance_chunk(self) -> bool:
        """Run ONE prefill chunk of the pending request (if any) between
        decode windows. The final chunk's logits seed the first generated
        token, and only then does the accumulated B=1 state land in the
        reserved slot row. Returns True if a chunk ran."""
        import jax.numpy as jnp
        p = self._pending
        if p is None:
            return False
        prompt = p.request.prompt
        c0 = p.consumed
        c1 = min(c0 + self._chunk, len(prompt))
        try:
            toks = jnp.asarray(prompt[None, c0:c1])
            logits, p.state = self._extend(self._params, p.state, toks,
                                           jnp.int32(c0))
            p.consumed = c1
            if c1 < len(prompt):
                return True
            nxt = self._sampler(logits, self._split_key())
            first = int(np.asarray(nxt)[0, 0])
            self._state = self._write(self._state, p.state,
                                      jnp.int32(p.slot))
        except Exception as exc:                            # noqa: BLE001
            self._free.append(p.slot)
            self._pending = None
            with self._lock:
                self._counters["failed"] += 1
            p.request.future.set_exception(exc)
            return True
        self._pending = None
        self._activate(p.request, p.slot, first)
        return True

    def _split_key(self):
        if self._key is None:
            return None
        import jax
        self._key, sub = jax.random.split(self._key)
        return sub

    def step(self) -> int:
        """One engine iteration: advance a pending chunked prefill, admit
        arrivals, then decode every occupied slot one fused window.
        Returns the number of slots that decoded (0 = idle). Call from a
        single driver thread only.

        Chunk admission is budgeted at one chunk per decode step — up to
        ``sync_every`` chunks per engine iteration, since the fused
        window below covers that many steps. Advancing only one chunk
        per *window* would stretch a chunked prompt's admission (and,
        under strict FCFS, everyone queued behind it) by the window
        length."""
        progressed = False
        for _ in range(self._sync):
            progressed |= self._advance_chunk()
            self._admit()
            if self._pending is None:
                break
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 1 if progressed else 0
        # Window length: picked per window from the power-of-two ladder up
        # to sync_every (so at most log2(sync_every)+1 executables exist)
        # by scoring useful tokens per unit cost. A window costs ~K decode
        # steps plus ~one step of sync/dispatch overhead, and a row only
        # uses min(K, its remaining budget) of it — tokens past a row's
        # retirement are speculative waste. Maximizing
        # sum(min(K, rem)) / (K + 1) batches syncs when budgets are deep
        # and shrinks the window when most rows are about to retire,
        # instead of burning a full window on a draining tail.
        rems = [s.request.max_new - len(s.generated)
                for s in self._slots if s is not None]
        k_eff, best, k = 1, -1.0, 1
        while k <= self._sync:
            score = sum(min(k, r) for r in rems) / (k + 1)
            if score > best:
                best, k_eff = score, k
            k = min(k * 2, self._sync) if k < self._sync else k * 2
        t0 = time.perf_counter()
        toks, self._state, self._tokens_dev, self._t_dev, key = \
            self._fused(k_eff)(self._params, self._state, self._tokens_dev,
                               self._t_dev, self._key)
        if self._key is not None:
            self._key = key
        toks = np.asarray(toks)           # ONE host sync per K-token window
        us_tok = (time.perf_counter() - t0) * 1e6 / (len(active) * k_eff)
        with self._lock:
            c = self._counters
            c["steps"] += k_eff
            c["decode_tokens"] += len(active) * k_eff
            c["occupancy_sum"] += len(active) * k_eff
            c["peak_occupancy"] = max(c["peak_occupancy"], len(active))
            c["host_syncs"] += 1
            self._ewma_us_tok = us_tok if self._ewma_us_tok == 0.0 \
                else 0.2 * us_tok + 0.8 * self._ewma_us_tok
        for i in active:
            slot = self._slots[i]
            # Slice this row's block to its own stop point: tokens past EOS
            # or the max_new budget were computed speculatively inside the
            # window and are simply dropped (the ring rows they touched are
            # rewritten on the slot's next admission).
            for j in range(k_eff):
                tok = int(toks[i, j])
                slot.generated.append(tok)
                slot.t += 1
                if (self._eos is not None and tok == self._eos) \
                        or len(slot.generated) >= slot.request.max_new:
                    self._retire(i)
                    break
        return len(active)

    def _retire(self, i: int) -> None:
        slot = self._slots[i]
        self._slots[i] = None
        self._free.append(i)
        out = np.concatenate([slot.request.prompt,
                              np.asarray(slot.generated, np.int32)])
        with self._lock:
            self._counters["retired"] += 1
            self._counters["generated_tokens"] += len(slot.generated)
        slot.request.future.set_result(out)

    # -- lifecycle -----------------------------------------------------------
    def warmup(self) -> "ServeEngine":
        """Compile every fused-window executable this engine can select
        (the power-of-two K ladder up to ``sync_every``) against throwaway
        state, so no window compiles mid-serving. Prompt-length prefill
        shapes still compile on first sight — warm those by submitting
        representative prompts."""
        import jax
        import jax.numpy as jnp
        from repro.models import transformer
        state = transformer.init_decode_state(self._cfg, self._ns, self._L)
        toks = jnp.zeros((self._ns, 1), jnp.int32)
        t = jnp.zeros((self._ns,), jnp.int32)
        key = None if self._key is None else jax.random.key(0)
        k = 1
        while k <= self._sync:
            out = self._fused(k)(self._params, state, toks, t, key)
            _, state, toks, t, key = out
            jax.block_until_ready(out)
            k = min(k * 2, self._sync) if k < self._sync else k * 2
        return self

    def start(self) -> "ServeEngine":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="serve-engine")
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    def stop(self) -> None:
        """Stop the loop and fail anything still queued or in flight."""
        with self._lock:
            self._closed = True
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        err = RuntimeError("engine stopped")
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(err)
        while self._ready:
            req = self._ready.popleft()
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(err)
        if self._pending is not None:
            p, self._pending = self._pending, None
            self._free.append(p.slot)
            p.request.future.set_exception(err)
        for i, slot in enumerate(self._slots):
            if slot is not None:
                self._slots[i] = None
                self._free.append(i)
                slot.request.future.set_exception(err)

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

    # -- introspection -------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return self._ns

    @property
    def context_len(self) -> int:
        return self._L

    def reset_stats(self) -> None:
        """Zero the counters (benchmarks: exclude warmup/compile from the
        measured window while keeping the warmed jit caches)."""
        with self._lock:
            for k in self._counters:
                self._counters[k] = 0

    def stats(self) -> dict:
        """Counters + derived occupancy; safe from any thread."""
        with self._lock:
            s = dict(self._counters)
            s["ewma_us_per_token"] = self._ewma_us_tok
        s["num_slots"] = self._ns
        s["free_slots"] = len(self._free)
        s["queue_depth"] = self._queue.qsize() + len(self._ready)
        s["mean_occupancy"] = (s["occupancy_sum"] / s["steps"]
                               if s["steps"] else 0.0)
        s["syncs_per_token"] = (s["host_syncs"] / s["generated_tokens"]
                                if s["generated_tokens"] else 0.0)
        return s

    def load(self) -> dict:
        """Cheap load report (the routing signal a fabric router uses):
        free KV slots, queued requests, and EWMA decode us/token. Safe
        from any thread, no full counter copy."""
        with self._lock:
            ewma = self._ewma_us_tok
            free = len(self._free)
        return {"num_slots": self._ns, "free_slots": free,
                "queue_depth": self._queue.qsize() + len(self._ready),
                "ewma_us_per_token": ewma}
