"""Continuous-batching decode engine over a slotted KV cache.

The lockstep serving path (drain a queue, pad a batch, run
prefill+decode, reply per batch) makes every request wait for a batch
boundary and the whole batch wait for its slowest member. This engine
replaces that with *iteration-level* scheduling:

  * the KV cache is a fixed pool of ``num_slots`` rows
    (``transformer.init_decode_state`` with batch = num_slots);
  * a persistent decode loop steps ALL occupied slots together, each at
    its own absolute position (``decode_step`` with a per-row ``t``
    vector — ring-position masking keeps ragged rows correct);
  * arrivals are admitted into free slots *between* decode steps: the
    request is prefilled alone at its exact prompt length and its
    per-layer state is written into the free row with
    ``transformer.write_decode_slot`` (a donated dynamic-update, so
    admission never copies or perturbs in-flight rows);
  * a sequence retires the moment it finishes (EOS or its ``max_new``
    budget) and its slot is immediately reusable — nobody waits for a
    batch-mate;
  * replies stream back per request through ``concurrent.futures``.

Exact-length prefill (no padding) keeps admission correct for every
``decode_supported`` architecture, including the recurrent ones
(RG-LRU / Mamba) whose state a padded prefill would pollute; jit caches
one prefill executable per distinct prompt length. Requests that cannot
ever fit (prompt + max_new > context_len) fail their own future at
submit time — they never poison a step, and the queue keeps serving
everyone else. A full pool queues requests (FCFS) instead of erroring.

MoE caveat: expert routing under a capacity factor couples rows through
the shared capacity budget, so MoE decode in a shared pool is not
bit-identical to serving the same request alone (dense / recurrent
stacks are).
"""

from __future__ import annotations

import dataclasses
import functools
import queue
import threading
import time
from concurrent import futures as cf
from typing import Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class _Request:
    prompt: np.ndarray            # [S] int32, detached copy
    max_new: int
    future: cf.Future
    submitted: float


@dataclasses.dataclass
class _Slot:
    request: _Request
    t: int                        # absolute position of the next token fed
    generated: list


class ServeEngine:
    """Continuous-batching serve engine.

    ``submit()`` is thread-safe and returns a ``concurrent.futures.Future``
    resolving to the full sequence (prompt + generated tokens, int32).
    Drive the engine either with ``start()`` (daemon decode loop — the
    serving deployment) or by calling ``step()`` directly from one thread
    (deterministic, used by tests and benchmarks).
    """

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 8,
                 context_len: int = 64, max_new: int = 16,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0):
        import jax
        from repro.models import transformer
        from repro.serve import decode as serve_lib

        if not cfg.decode_supported:
            raise ValueError(f"{cfg.name} has no autoregressive decode step")
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self._cfg = cfg
        self._params = params
        self._ns = num_slots
        self._L = context_len
        self._max_new = max_new
        self._eos = eos_id
        self._temp = temperature
        self._key = jax.random.key(seed) if temperature else None

        self._state = transformer.init_decode_state(cfg, num_slots,
                                                    context_len)
        self._slots: list[Optional[_Slot]] = [None] * num_slots
        self._free: list[int] = list(range(num_slots - 1, -1, -1))
        self._tokens = np.zeros((num_slots, 1), np.int32)   # next feed
        self._t = np.zeros((num_slots,), np.int32)          # per-row pos

        self._decode = jax.jit(serve_lib.make_serve_step(cfg, temperature),
                               donate_argnums=(1,))

        def _prefill_fn(params, tokens, key=None):
            logits, state = transformer.prefill(cfg, params, tokens=tokens,
                                                context_len=context_len)
            nxt = serve_lib.sample_from_logits(logits[:, -1:], key,
                                               temperature)
            return nxt, state

        # One executable per distinct prompt length (jit's shape cache).
        self._prefill = jax.jit(_prefill_fn)
        self._write = jax.jit(
            functools.partial(transformer.write_decode_slot, cfg),
            donate_argnums=(0,))

        self._queue: queue.Queue[_Request] = queue.Queue()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._lock = threading.Lock()                       # stats + lifecycle
        self._counters = dict(submitted=0, admitted=0, retired=0, failed=0,
                              steps=0, decode_tokens=0, generated_tokens=0,
                              occupancy_sum=0, peak_occupancy=0)
        # EWMA decode-step microseconds per token: the routing signal a
        # load balancer uses to weigh this engine against its siblings.
        self._ewma_us_tok = 0.0

    # -- client side ---------------------------------------------------------
    def submit(self, prompt, max_new: Optional[int] = None) -> cf.Future:
        """Enqueue one request; resolves to [S + n_generated] int32.

        The prompt is copied (a transport-owned zero-copy view is safe to
        hand in; its lease is released as soon as submit returns). A
        request that cannot fit the slot ring fails its own future here —
        per-request delivery, no effect on its neighbours.
        """
        fut: cf.Future = cf.Future()
        prompt = np.asarray(prompt, np.int32).reshape(-1).copy()
        mn = self._max_new if max_new is None else int(max_new)
        if prompt.size == 0:
            fut.set_exception(ValueError("empty prompt"))
            return fut
        if prompt.size + mn > self._L:
            fut.set_exception(ValueError(
                f"prompt ({prompt.size}) + max_new ({mn}) exceeds the "
                f"engine's context_len ({self._L})"))
            return fut
        with self._lock:
            # The put happens under the same lock stop() takes before
            # draining, so a request can never slip into the queue after
            # the drain and hang its caller.
            if self._closed:
                fut.set_exception(RuntimeError("engine stopped"))
                return fut
            self._counters["submitted"] += 1
            self._queue.put(_Request(prompt, mn, fut, time.monotonic()))
        self._wake.set()
        return fut

    # -- engine side ---------------------------------------------------------
    def _admit(self) -> None:
        """Move queued requests into free slots: exact-length prefill, then
        write the fresh per-layer state into the slot's cache row."""
        import jax.numpy as jnp
        while self._free:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if not req.future.set_running_or_notify_cancel():
                continue                                    # cancelled
            i = self._free.pop()
            try:
                key = self._split_key()
                nxt, slot_state = self._prefill(
                    self._params, jnp.asarray(req.prompt[None]), key)
                self._state = self._write(self._state, slot_state,
                                          jnp.int32(i))
                first = int(np.asarray(nxt)[0, 0])
            except Exception as exc:                        # noqa: BLE001
                # Per-request failure delivery: the slot goes straight back
                # and the step proceeds for everyone else.
                self._free.append(i)
                with self._lock:
                    self._counters["failed"] += 1
                req.future.set_exception(exc)
                continue
            self._slots[i] = _Slot(request=req, t=len(req.prompt),
                                   generated=[first])
            self._t[i] = len(req.prompt)
            self._tokens[i, 0] = first
            with self._lock:
                self._counters["admitted"] += 1
            if (self._eos is not None and first == self._eos) \
                    or req.max_new <= 1:
                self._retire(i)

    def _split_key(self):
        if self._key is None:
            return None
        import jax
        self._key, sub = jax.random.split(self._key)
        return sub

    def step(self) -> int:
        """One engine iteration: admit arrivals, then decode every occupied
        slot one token. Returns the number of slots that decoded (0 =
        idle). Call from a single driver thread only."""
        import jax.numpy as jnp
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        nxt, self._state = self._decode(
            self._params, self._state, jnp.asarray(self._tokens),
            jnp.asarray(self._t), self._split_key())
        nxt = np.asarray(nxt)                       # host sync ends the step
        us_tok = (time.perf_counter() - t0) * 1e6 / len(active)
        with self._lock:
            c = self._counters
            c["steps"] += 1
            c["decode_tokens"] += len(active)
            c["occupancy_sum"] += len(active)
            c["peak_occupancy"] = max(c["peak_occupancy"], len(active))
            self._ewma_us_tok = us_tok if self._ewma_us_tok == 0.0 \
                else 0.2 * us_tok + 0.8 * self._ewma_us_tok
        for i in active:
            slot = self._slots[i]
            tok = int(nxt[i, 0])
            slot.generated.append(tok)
            slot.t += 1
            self._t[i] = slot.t
            self._tokens[i, 0] = tok
            if (self._eos is not None and tok == self._eos) \
                    or len(slot.generated) >= slot.request.max_new:
                self._retire(i)
        return len(active)

    def _retire(self, i: int) -> None:
        slot = self._slots[i]
        self._slots[i] = None
        self._free.append(i)
        self._tokens[i, 0] = 0
        self._t[i] = 0
        out = np.concatenate([slot.request.prompt,
                              np.asarray(slot.generated, np.int32)])
        with self._lock:
            self._counters["retired"] += 1
            self._counters["generated_tokens"] += len(slot.generated)
        slot.request.future.set_result(out)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServeEngine":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="serve-engine")
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    def stop(self) -> None:
        """Stop the loop and fail anything still queued or in flight."""
        with self._lock:
            self._closed = True
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        err = RuntimeError("engine stopped")
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(err)
        for i, slot in enumerate(self._slots):
            if slot is not None:
                self._slots[i] = None
                self._free.append(i)
                slot.request.future.set_exception(err)

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

    # -- introspection -------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return self._ns

    @property
    def context_len(self) -> int:
        return self._L

    def reset_stats(self) -> None:
        """Zero the counters (benchmarks: exclude warmup/compile from the
        measured window while keeping the warmed jit caches)."""
        with self._lock:
            for k in self._counters:
                self._counters[k] = 0

    def stats(self) -> dict:
        """Counters + derived occupancy; safe from any thread."""
        with self._lock:
            s = dict(self._counters)
            s["ewma_us_per_token"] = self._ewma_us_tok
        s["num_slots"] = self._ns
        s["free_slots"] = len(self._free)
        s["queue_depth"] = self._queue.qsize()
        s["mean_occupancy"] = (s["occupancy_sum"] / s["steps"]
                               if s["steps"] else 0.0)
        return s

    def load(self) -> dict:
        """Cheap load report (the routing signal a fabric router uses):
        free KV slots, queued requests, and EWMA decode us/token. Safe
        from any thread, no full counter copy."""
        with self._lock:
            ewma = self._ewma_us_tok
            free = len(self._free)
        return {"num_slots": self._ns, "free_slots": free,
                "queue_depth": self._queue.qsize(),
                "ewma_us_per_token": ewma}
