"""Continuous-batching decode engine over a slotted KV cache.

The lockstep serving path (drain a queue, pad a batch, run
prefill+decode, reply per batch) makes every request wait for a batch
boundary and the whole batch wait for its slowest member. This engine
replaces that with *iteration-level* scheduling:

  * the KV cache is a fixed pool of ``num_slots`` rows
    (``transformer.init_decode_state`` with batch = num_slots);
  * a persistent decode loop steps ALL occupied slots together, each at
    its own absolute position (``decode_step`` with a per-row ``t``
    vector — ring-position masking keeps ragged rows correct);
  * decode runs in *fused windows*: sampling and the per-row
    feed-token/position updates live inside one jitted ``lax.scan``
    (``serve.decode.make_fused_serve_step``), so the feed tokens, the
    position vector, and the PRNG key stay device-resident and the host
    syncs one ``[num_slots, K]`` token block per window instead of one
    token per step. ``sync_every`` caps K (default 8); each window's K
    is picked from the power-of-two ladder by useful-tokens-per-cost
    (see ``step``), so draining tails shrink the window instead of
    burning speculative steps and at most log2(sync_every)+1
    executables exist. EOS / ``max_new``
    retirement is detected on the sync by slicing each row's block to
    its own stop point — bit-identical to syncing every step, because
    the scan body IS the single-step path;
  * ``decode_impl`` picks the attention leaf ("auto" | "dense" |
    "flash"): flash routes through the ``kernels.ops`` dispatcher — the
    one-HBM-pass flash-decode kernel on TPU, its jnp oracle as a native
    XLA executable elsewhere — with the ring-validity mask handed to the
    kernel as its precomputed ``valid`` mask;
  * arrivals are admitted into free slots *between* windows: the
    request is prefilled alone at its exact prompt length and its
    per-layer state is written into the free row with
    ``transformer.write_decode_slot`` (a donated dynamic-update, so
    admission never copies or perturbs in-flight rows);
  * with ``prefill_chunk`` set, a long prompt prefills in fixed-size
    chunks interleaved between decode windows (``prefill_extend``
    against a reserved slot's own B=1 state), so a long prompt never
    stalls in-flight decode for its full prefill. Chunking needs an
    attention-only stack; other stacks (and short prompts) fall back to
    the monolithic exact-length prefill. Admission order stays strict
    FCFS: while a chunked prefill is in progress, later arrivals wait;
  * a sequence retires the moment it finishes (EOS or its ``max_new``
    budget) and its slot is immediately reusable — nobody waits for a
    batch-mate;
  * replies stream back per request through ``concurrent.futures``.

Exact-length prefill (no padding) keeps admission correct for every
``decode_supported`` architecture, including the recurrent ones
(RG-LRU / Mamba) whose state a padded prefill would pollute; jit caches
one prefill executable per distinct prompt length. Requests that cannot
ever fit (prompt + max_new > context_len) fail their own future at
submit time — they never poison a step, and the queue keeps serving
everyone else. A full pool queues requests (FCFS) instead of erroring.

Paged KV mode (``page_size`` set): full-context ATTN layers swap their
flat ``[num_slots, L]`` rings for a shared pool of ``num_pages``
fixed-size pages plus a host-resident ``[num_slots, n_log]`` page table
(mutated freely on admission/retirement, re-uploaded — a few hundred
bytes — once per fused window),
so a row's cache footprint is ``ceil((prompt+max_new)/page_size)``
pages instead of a full max-L ring and the concurrency limit is total
*pages*, not rows × max_L. Admission reserves a row's whole page budget
up front (deadlock-free: a decode window can never run out mid-flight —
"appending a page on a boundary crossing" is the pre-assigned page-table
entry coming live as ``t`` crosses it), retirement refcount-releases the
pages and re-points the row's table entries at the trash page (physical
page 0), where free rows' and speculative post-retirement writes land
harmlessly. On top of paging, a refcounted prefix cache
(``serve.paging.PrefixCache``) lets a prompt sharing a cached
page-aligned prefix skip that prefix's prefill: its leading page-table
entries alias the shared pages (copy-on-write by construction — shared
pages are fully prompt-covered, and decode writes start at the prompt
end) and only the suffix runs through ``prefill_extend``. Windowed
(SWA/local) rings and recurrent state stay per-row — already
footprint-bounded — which also scopes the prefix cache to causal
attention-only stacks.

MoE caveat: expert routing under a capacity factor couples rows through
the shared capacity budget, so MoE decode in a shared pool is not
bit-identical to serving the same request alone (dense / recurrent
stacks are).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import queue
import threading
import time
from concurrent import futures as cf
from typing import Any, Optional

import numpy as np

from repro.core import telemetry
from repro.models.config import ModelConfig

_CHUNKABLE_KINDS = {"attn", "swa", "local"}


def _pow2ceil(n: int) -> int:
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class _Request:
    prompt: np.ndarray            # [S] int32, detached copy
    max_new: int
    future: cf.Future
    submitted: float
    # Trace attribution, captured on the submitting (RPC handler) thread:
    # the engine thread has no contextvar state, so spans for this request
    # are recorded against this explicit context.
    ctx: Optional[telemetry.TraceContext] = None
    wall: float = 0.0             # submit wall-clock (TTFT / span anchors)


@dataclasses.dataclass
class _Slot:
    request: _Request
    t: int                        # absolute position of the next token fed
    generated: list


@dataclasses.dataclass
class _PendingPrefill:
    """A chunked prefill in flight: the request holds its reserved slot
    while its prompt streams through ``prefill_extend`` one chunk per
    engine step, against its own B=1 state."""
    request: _Request
    slot: int
    state: Any                    # B=1 decode state (chunk-extended)
    consumed: int                 # prompt tokens already prefilled
    start_page: int = 0           # leading shared prefix pages (paged mode)


class ServeEngine:
    """Continuous-batching serve engine.

    ``submit()`` is thread-safe and returns a ``concurrent.futures.Future``
    resolving to the full sequence (prompt + generated tokens, int32).
    Drive the engine either with ``start()`` (daemon decode loop — the
    serving deployment) or by calling ``step()`` directly from one thread
    (deterministic, used by tests and benchmarks).
    """

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 8,
                 context_len: int = 64, max_new: int = 16,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0, sync_every: int = 8,
                 top_k: Optional[int] = None, decode_impl: str = "auto",
                 prefill_chunk: Optional[int] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 prefix_cache: bool = True):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer
        from repro.serve import decode as serve_lib

        if not cfg.decode_supported:
            raise ValueError(f"{cfg.name} has no autoregressive decode step")
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if decode_impl not in ("auto", "dense", "flash"):
            raise ValueError(f"decode_impl must be auto|dense|flash, "
                             f"got {decode_impl!r}")
        if page_size is not None and page_size < 1:
            raise ValueError("page_size must be >= 1")
        self._cfg = cfg
        self._params = params
        self._ns = num_slots
        self._L = context_len
        self._max_new = max_new
        self._eos = eos_id
        self._temp = temperature
        self._top_k = top_k
        self._impl = decode_impl
        self._sync = sync_every
        self._key = jax.random.key(seed) if temperature else None

        kinds = set(cfg.pattern) | set(cfg.remainder)
        # Paged KV pool geometry. The internal ring modulus is context_len
        # rounded UP to whole pages (L_pad): submit() still rejects
        # prompt+max_new > context_len, so positions never wrap for any
        # modulus >= context_len and the ring-validity math is unchanged.
        # Stacks with no full-context ATTN layer (pure windowed/recurrent)
        # have nothing to page — they accept the knobs but run the flat
        # per-row layout with an unlimited "pool".
        self._paged = page_size is not None
        if self._paged:
            self._ps = int(page_size)
            self._n_log = -(-context_len // self._ps)
            self._Lp = self._n_log * self._ps
            self._has_paged = "attn" in kinds
            self._P = (int(num_pages) if num_pages is not None
                       else num_slots * self._n_log)
            if self._has_paged and self._P < self._n_log:
                # Not fatal — short requests still fit — but a max-size
                # request can never be admitted; submit() rejects per-request.
                pass
        else:
            self._ps = 0
            self._Lp = context_len
            self._has_paged = False
        # Compact windows: with every cache leaf behind the page table
        # (attention-only stack), the fused window's batch width is a free
        # choice — the executable sees [W] page-table rows, tokens and
        # positions, never the pool's row count — so windows run at the
        # ACTIVE row count (padded up to a compiled width) and idle slots
        # cost nothing. The flat ring cannot do this without physically
        # compacting KV rows, which is the structural reason extra paged
        # admission capacity is ~free. Stacks with per-row state leaves
        # (SWA rings, recurrent, conv) keep full-width windows.
        self._compact = self._has_paged and kinds == {"attn"}
        self._chunk = prefill_chunk
        self._can_chunk = (prefill_chunk is not None
                           and kinds <= _CHUNKABLE_KINDS
                           and not cfg.conv_pos)
        if prefill_chunk is not None:
            ring = min((min(self._Lp, cfg.window or self._Lp)
                        if k in ("swa", "local") else self._Lp)
                       for k in kinds)
            if not 1 <= prefill_chunk <= ring:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be in [1, "
                    f"{ring}] (the smallest cache ring) — a larger chunk "
                    "would overwrite slots its own queries still attend to")

        if self._has_paged:
            # Physical pool is P usable pages + the trash page (id 0).
            self._state = transformer.init_decode_state(
                cfg, num_slots, self._Lp, page_size=self._ps,
                num_pages=self._P + 1)
            # The page table lives on HOST (tiny int32 [ns, n_log]): it
            # mutates on every admission/retirement, and host writes are
            # free where device .at[] updates were one jit call each; the
            # fused window re-uploads the ~KB table once per window.
            self._pages_tab = np.zeros((num_slots, self._n_log), np.int32)
            self._free_pages: list[int] = list(range(self._P, 0, -1))
            self._page_rc: list[int] = [0] * (self._P + 1)
            self._row_pages: list[Optional[list[int]]] = [None] * num_slots
            self._ppr_ewma = 0.0            # pages per admitted request
            prefix_ok = (prefix_cache and cfg.causal and not cfg.conv_pos
                         and kinds <= {"attn"})
            if prefix_ok:
                from repro.serve.paging import PrefixCache
                self._prefix: Optional[PrefixCache] = PrefixCache(self._ps)
            else:
                self._prefix = None
        else:
            self._state = transformer.init_decode_state(cfg, num_slots,
                                                        self._Lp)
            self._pages_tab = None
            self._prefix = None
        self._slots: list[Optional[_Slot]] = [None] * num_slots
        self._free: list[int] = list(range(num_slots - 1, -1, -1))
        # Device-resident hot state: the feed tokens and per-row positions
        # live on device between syncs (rebuilding them from host numpy
        # every step was a measurable per-step tax), and the fused window
        # threads them through donated buffers.
        self._tokens_dev = jnp.zeros((num_slots, 1), jnp.int32)
        self._t_dev = jnp.zeros((num_slots,), jnp.int32)

        # Fused-window executables, shared across engine instances via the
        # lru cache in serve.decode (keyed on every static knob, attn_impl
        # included — the kernel-vs-dense choice is baked at trace time).
        self._fused = functools.partial(
            serve_lib.cached_fused_step, cfg, temperature=temperature,
            top_k=top_k, attn_impl=decode_impl)
        self._sampler = jax.jit(serve_lib.make_sampler(temperature, top_k))

        Lp = self._Lp

        def _prefill_fn(params, tokens, key=None):
            logits, state = transformer.prefill(cfg, params, tokens=tokens,
                                                context_len=Lp)
            nxt = serve_lib.make_sampler(temperature, top_k)(
                logits[:, -1:], key)
            return nxt, state

        # One executable per distinct prompt length (jit's shape cache).
        self._prefill = jax.jit(_prefill_fn)
        self._extend = jax.jit(
            functools.partial(transformer.prefill_extend, cfg),
            donate_argnums=(1,))
        self._write = jax.jit(
            functools.partial(transformer.write_decode_slot, cfg),
            donate_argnums=(0,))
        if self._has_paged:
            ps = self._ps

            def _write_paged_fn(state, slot_state, i, row_pages,
                                start_page):
                return transformer.write_paged_slot(
                    cfg, state, slot_state, i, row_pages, start_page, ps)

            self._write_paged = jax.jit(_write_paged_fn,
                                        donate_argnums=(0,))
            self._gather = jax.jit(
                lambda state, i, row_pages: transformer.gather_paged_slot(
                    cfg, state, i, row_pages, ps))

        def _row_write_fn(tokens, t, i, tok, tval):
            return tokens.at[i, 0].set(tok), t.at[i].set(tval)

        self._row_write = jax.jit(_row_write_fn, donate_argnums=(0, 1))

        self._queue: queue.Queue[_Request] = queue.Queue()
        self._ready: collections.deque[_Request] = collections.deque()
        self._pending: Optional[_PendingPrefill] = None
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._lock = threading.Lock()                       # stats + lifecycle
        self._counters = dict(submitted=0, admitted=0, retired=0, failed=0,
                              steps=0, decode_tokens=0, generated_tokens=0,
                              occupancy_sum=0, peak_occupancy=0,
                              host_syncs=0, prefix_tokens_reused=0,
                              param_swaps=0)
        # Weight hot-swap handoff: (params, applied-event), installed by
        # the engine thread at the top of its next step.
        self._pending_swap: Optional[tuple[Any, threading.Event]] = None
        # Node attribution for spans recorded on the engine thread (which
        # never gets a WorkerContext); captured here, on the constructing
        # node's thread.
        self._node = telemetry.node_name()
        # EWMA decode-step microseconds per token: the routing signal a
        # load balancer uses to weigh this engine against its siblings.
        self._ewma_us_tok = 0.0

    # -- client side ---------------------------------------------------------
    def submit(self, prompt, max_new: Optional[int] = None) -> cf.Future:
        """Enqueue one request; resolves to [S + n_generated] int32.

        The prompt is copied (a transport-owned zero-copy view is safe to
        hand in; its lease is released as soon as submit returns). A
        request that cannot fit the slot ring fails its own future here —
        per-request delivery, no effect on its neighbours.
        """
        fut: cf.Future = cf.Future()
        prompt = np.asarray(prompt, np.int32).reshape(-1).copy()
        mn = self._max_new if max_new is None else int(max_new)
        if prompt.size == 0:
            fut.set_exception(ValueError("empty prompt"))
            return fut
        if prompt.size + mn > self._L:
            fut.set_exception(ValueError(
                f"prompt ({prompt.size}) + max_new ({mn}) exceeds the "
                f"engine's context_len ({self._L})"))
            return fut
        if self._has_paged and self._page_need(prompt.size, mn) > self._P:
            fut.set_exception(ValueError(
                f"request needs {self._page_need(prompt.size, mn)} KV "
                f"pages; the pool only has {self._P}"))
            return fut
        with self._lock:
            # The put happens under the same lock stop() takes before
            # draining, so a request can never slip into the queue after
            # the drain and hang its caller.
            if self._closed:
                fut.set_exception(RuntimeError("engine stopped"))
                return fut
            self._counters["submitted"] += 1
            ctx = telemetry.current_context()
            self._queue.put(_Request(
                prompt, mn, fut, time.monotonic(),
                ctx=ctx if ctx is not None and ctx.sampled else None,
                wall=time.time()))
        self._wake.set()
        return fut

    def swap_params(self, params, block: bool = True,
                    timeout_s: float = 60.0) -> None:
        """Hot-swap the model weights (a zero-downtime rollout's engine
        half). The new tree is installed by the engine thread *between*
        decode windows — admission and decode both see a consistent tree
        for any one window, never a mix. Because params are a per-call
        operand to every compiled executable, a shape/dtype-identical
        swap reuses the entire warmed ladder: no recompile, no re-warm
        cost (``EngineServer.load_version`` enforces shape identity by
        restoring against the current tree).

        With ``block=True`` (and a running engine thread) waits until the
        swap has been applied. When the engine is driven by external
        ``step()`` calls, the swap lands on the caller's next step.
        """
        done = threading.Event()
        with self._lock:
            if self._closed:
                raise RuntimeError("engine stopped")
            prev = self._pending_swap
            self._pending_swap = (params, done)
        if prev is not None:
            prev[1].set()       # superseded before it was applied
        self._wake.set()
        if block and self._thread is not None:
            if not done.wait(timeout_s):
                raise TimeoutError("param swap not applied within "
                                   f"{timeout_s}s")

    def _apply_pending_swap(self) -> None:
        with self._lock:
            swap, self._pending_swap = self._pending_swap, None
        if swap is None:
            return
        params, done = swap
        self._params = params
        with self._lock:
            self._counters["param_swaps"] += 1
        done.set()

    # -- page accounting (paged mode, engine thread only) --------------------
    def _page_need(self, prompt_len: int, max_new: int) -> int:
        total = min(prompt_len + max_new, self._Lp)
        return -(-total // self._ps)

    def _incref(self, pid: int) -> None:
        self._page_rc[pid] += 1

    def _decref(self, pid: int) -> None:
        self._page_rc[pid] -= 1
        if self._page_rc[pid] == 0:
            self._free_pages.append(pid)

    def _alloc_pages(self, n: int) -> Optional[list[int]]:
        """Take ``n`` pages off the free list (each born with refcount 1),
        evicting refcount-zero-able prefix-cache entries LRU-first under
        pressure. None = the pool genuinely cannot satisfy ``n`` right now
        (admission blocks FCFS until retirements release pages)."""
        while len(self._free_pages) < n:
            if self._prefix is None or not self._prefix.evict_one(self._decref):
                return None
        out = [self._free_pages.pop() for _ in range(n)]
        for pid in out:
            self._page_rc[pid] = 1
        return out

    def _release_pages(self, pages: Optional[list[int]]) -> None:
        if pages:
            for pid in pages:
                self._decref(pid)

    def _pages_arr(self, row_pages: list[int]):
        """Row page list padded to the full logical length with trash-page
        entries (speculative writes past the reservation land there).
        Host numpy: it feeds both the host page table and jit operands
        (converted at the call boundary)."""
        pad = [0] * (self._n_log - len(row_pages))
        return np.asarray(row_pages + pad, np.int32)

    def _register_prefix(self, prompt: np.ndarray,
                         row_pages: list[int]) -> None:
        if self._prefix is not None:
            self._prefix.insert(prompt, row_pages, self._incref,
                                self._decref)

    def _window_width(self, n: int) -> int:
        """Compact-window batch width for ``n`` active rows: the smallest
        power-of-two >= n, capped at ``num_slots`` — so at most
        log2(num_slots)+2 width shapes ever compile per window length."""
        w = 1
        while w < n and w < self._ns:
            w *= 2
        return min(w, self._ns)

    # -- engine side ---------------------------------------------------------
    def _activate(self, req: _Request, i: int, first: int,
                  path: str = "direct") -> None:
        """Mark slot ``i`` live: host bookkeeping + the device-resident
        feed-token/position rows (one donated row write, no full-array
        host->device rebuild). Compact-window engines skip the device
        write: their windows rebuild the [W] feed operands from host slot
        state anyway, so the per-admission jit call would be pure tax.

        The first generated token exists here, so this is where
        time-to-first-token lands — classed by prefill path (``direct``
        vs ``chunked``), the two populations whose TTFT distributions an
        SLO policy must not average together."""
        import jax.numpy as jnp
        self._slots[i] = _Slot(request=req, t=len(req.prompt),
                               generated=[first])
        if req.wall:
            telemetry.metrics().histogram(
                f"engine.ttft_us.{path}").record(
                    (time.time() - req.wall) * 1e6)
        if not self._compact:
            self._tokens_dev, self._t_dev = self._row_write(
                self._tokens_dev, self._t_dev, jnp.int32(i), jnp.int32(first),
                jnp.int32(len(req.prompt)))
        with self._lock:
            self._counters["admitted"] += 1
            self._counters["host_syncs"] += 1   # the first-token pull
        if (self._eos is not None and first == self._eos) \
                or req.max_new <= 1:
            self._retire(i)

    def _admit(self) -> None:
        """Move queued requests into free slots: exact-length prefill, then
        write the fresh per-layer state into the slot's cache row. Long
        prompts (with ``prefill_chunk`` on an attention-only stack) are
        parked as a _PendingPrefill instead and stream through
        ``_advance_chunk`` one chunk per step; admission order stays
        strict FCFS, so later arrivals wait behind an in-flight chunked
        prefill rather than jumping it.

        Paged mode reserves the row's whole page budget here (shared
        prefix pages + freshly allocated owned pages); a pool that cannot
        satisfy the head request blocks admission (FCFS) until
        retirements — or prefix-cache eviction — free pages. On a prefix
        hit the shared pages are gathered into a flat B=1 view and only
        the prompt *suffix* runs through ``prefill_extend``; the
        copy-on-write scatter then lands just the owned pages."""
        import jax.numpy as jnp
        while True:
            try:
                self._ready.append(self._queue.get_nowait())
            except queue.Empty:
                break
        while self._free and self._ready:
            req = self._ready[0]
            chunked = self._can_chunk and len(req.prompt) > self._chunk
            if chunked and self._pending is not None:
                return                          # FCFS: wait for the pending
            shared: list[int] = []
            row_pages: Optional[list[int]] = None
            if self._has_paged:
                n_need = self._page_need(len(req.prompt), req.max_new)
                if self._prefix is not None:
                    shared = self._prefix.lookup(req.prompt)
                owned = self._alloc_pages(n_need - len(shared))
                if owned is None:
                    return      # pool exhausted: FCFS-block at the head
                for pid in shared:
                    self._incref(pid)
                row_pages = shared + owned
            self._ready.popleft()
            if not req.future.set_running_or_notify_cancel():
                self._release_pages(row_pages)
                continue                                    # cancelled
            i = self._free.pop()
            if req.ctx is not None:
                # Admission wait: submit RPC -> a free slot (and, in paged
                # mode, the page budget) became this request's.
                telemetry.record_span("admission", req.ctx, req.wall,
                                      time.time() - req.wall,
                                      node=self._node, slot=i)
            c = len(shared)
            if self._has_paged:
                self._row_pages[i] = row_pages
                self._ppr_ewma = (float(len(row_pages))
                                  if self._ppr_ewma == 0.0 else
                                  0.2 * len(row_pages) + 0.8 * self._ppr_ewma)
                if c:
                    with self._lock:
                        self._counters["prefix_tokens_reused"] += c * self._ps
            if chunked:
                from repro.models import transformer
                if c:
                    state = self._gather(self._state, jnp.int32(i),
                                         self._pages_arr(row_pages))
                else:
                    state = transformer.init_decode_state(self._cfg, 1,
                                                          self._Lp)
                self._pending = _PendingPrefill(
                    request=req, slot=i, state=state,
                    consumed=c * self._ps, start_page=c)
                continue
            try:
                t0w, t0 = time.time(), time.perf_counter()
                key = self._split_key()
                if c:
                    flat = self._gather(self._state, jnp.int32(i),
                                        self._pages_arr(row_pages))
                    logits, slot_state = self._extend(
                        self._params, flat,
                        jnp.asarray(req.prompt[None, c * self._ps:]),
                        jnp.int32(c * self._ps))
                    nxt = self._sampler(logits, key)
                else:
                    nxt, slot_state = self._prefill(
                        self._params, jnp.asarray(req.prompt[None]), key)
                if self._has_paged:
                    arr = self._pages_arr(row_pages)
                    self._state = self._write_paged(
                        self._state, slot_state, jnp.int32(i), arr,
                        jnp.int32(c))
                    self._pages_tab[i] = arr
                    self._register_prefix(req.prompt, row_pages)
                else:
                    self._state = self._write(self._state, slot_state,
                                              jnp.int32(i))
                first = int(np.asarray(nxt)[0, 0])
                if req.ctx is not None:
                    telemetry.record_span(
                        "prefill", req.ctx, t0w,
                        time.perf_counter() - t0, node=self._node,
                        path="direct",
                        tokens=len(req.prompt) - c * self._ps)
            except Exception as exc:                        # noqa: BLE001
                # Per-request failure delivery: the slot goes straight back
                # and the step proceeds for everyone else.
                self._free.append(i)
                if self._has_paged:
                    self._release_pages(self._row_pages[i])
                    self._row_pages[i] = None
                    self._pages_tab[i] = 0
                with self._lock:
                    self._counters["failed"] += 1
                req.future.set_exception(exc)
                continue
            self._activate(req, i, first, path="direct")

    def _advance_chunk(self) -> bool:
        """Run ONE prefill chunk of the pending request (if any) between
        decode windows. The final chunk's logits seed the first generated
        token, and only then does the accumulated B=1 state land in the
        reserved slot row. Returns True if a chunk ran."""
        import jax.numpy as jnp
        p = self._pending
        if p is None:
            return False
        prompt = p.request.prompt
        c0 = p.consumed
        c1 = min(c0 + self._chunk, len(prompt))
        t0w, t0 = time.time(), time.perf_counter()
        try:
            toks = jnp.asarray(prompt[None, c0:c1])
            logits, p.state = self._extend(self._params, p.state, toks,
                                           jnp.int32(c0))
            p.consumed = c1
            if p.request.ctx is not None:
                telemetry.record_span("prefill", p.request.ctx, t0w,
                                      time.perf_counter() - t0,
                                      node=self._node, path="chunked",
                                      tokens=c1 - c0)
            if c1 < len(prompt):
                return True
            nxt = self._sampler(logits, self._split_key())
            first = int(np.asarray(nxt)[0, 0])
            if self._has_paged:
                rp = self._row_pages[p.slot]
                arr = self._pages_arr(rp)
                self._state = self._write_paged(
                    self._state, p.state, jnp.int32(p.slot), arr,
                    jnp.int32(p.start_page))
                self._pages_tab[p.slot] = arr
                self._register_prefix(p.request.prompt, rp)
            else:
                self._state = self._write(self._state, p.state,
                                          jnp.int32(p.slot))
        except Exception as exc:                            # noqa: BLE001
            self._free.append(p.slot)
            if self._has_paged:
                self._release_pages(self._row_pages[p.slot])
                self._row_pages[p.slot] = None
                self._pages_tab[p.slot] = 0
            self._pending = None
            with self._lock:
                self._counters["failed"] += 1
            p.request.future.set_exception(exc)
            return True
        self._pending = None
        self._activate(p.request, p.slot, first, path="chunked")
        return True

    def _split_key(self):
        if self._key is None:
            return None
        import jax
        self._key, sub = jax.random.split(self._key)
        return sub

    def step(self) -> int:
        """One engine iteration: advance a pending chunked prefill, admit
        arrivals, then decode every occupied slot one fused window.
        Returns the number of slots that decoded (0 = idle). Call from a
        single driver thread only.

        Chunk admission is budgeted at one chunk per decode step — up to
        ``sync_every`` chunks per engine iteration, since the fused
        window below covers that many steps. Advancing only one chunk
        per *window* would stretch a chunked prompt's admission (and,
        under strict FCFS, everyone queued behind it) by the window
        length."""
        self._apply_pending_swap()      # between windows, before admission
        progressed = False
        for _ in range(self._sync):
            progressed |= self._advance_chunk()
            self._admit()
            if self._pending is None:
                break
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 1 if progressed else 0
        # Window length: picked per window from the power-of-two ladder up
        # to sync_every (so at most log2(sync_every)+1 executables exist)
        # by scoring useful tokens per unit cost. A window costs ~K decode
        # steps plus ~one step of sync/dispatch overhead, and a row only
        # uses min(K, its remaining budget) of it — tokens past a row's
        # retirement are speculative waste. Maximizing
        # sum(min(K, rem)) / (K + 1) batches syncs when budgets are deep
        # and shrinks the window when most rows are about to retire,
        # instead of burning a full window on a draining tail.
        rems = [s.request.max_new - len(s.generated)
                for s in self._slots if s is not None]
        k_eff, best, k = 1, -1.0, 1
        while k <= self._sync:
            score = sum(min(k, r) for r in rems) / (k + 1)
            if score > best:
                best, k_eff = score, k
            k = min(k * 2, self._sync) if k < self._sync else k * 2
        t0w = time.time()
        t0 = time.perf_counter()
        row_of = None
        if self._compact:
            # Window width = active count padded up to a compiled ladder
            # width: the executable reads [W] page-table rows / feed
            # tokens / positions, never the slot count, so idle capacity
            # rows cost zero compute. Pad rows carry an all-trash table
            # and t=0 (all-invalid attention -> zeros); their writes land
            # in the trash page. The feed operands rebuild from host slot
            # state — a few dozen bytes per window.
            W = self._window_width(len(active))
            toks_w = np.zeros((W, 1), np.int32)
            t_w = np.zeros((W,), np.int32)
            pages_w = np.zeros((W, self._n_log), np.int32)
            for w, i in enumerate(active):
                s = self._slots[i]
                toks_w[w, 0] = s.generated[-1]
                t_w[w] = s.t
                pages_w[w] = self._pages_tab[i]
            toks, self._state, _, _, key = \
                self._fused(k_eff)(self._params, self._state, toks_w, t_w,
                                   self._key, pages_w)
            row_of = {i: w for w, i in enumerate(active)}
        elif self._has_paged:
            toks, self._state, self._tokens_dev, self._t_dev, key = \
                self._fused(k_eff)(self._params, self._state,
                                   self._tokens_dev, self._t_dev, self._key,
                                   self._pages_tab)
        else:
            toks, self._state, self._tokens_dev, self._t_dev, key = \
                self._fused(k_eff)(self._params, self._state,
                                   self._tokens_dev, self._t_dev, self._key)
        if self._key is not None:
            self._key = key
        toks = np.asarray(toks)           # ONE host sync per K-token window
        win_dur = time.perf_counter() - t0
        us_tok = win_dur * 1e6 / (len(active) * k_eff)
        # Each sampled in-flight request gets this window as a span — the
        # loop is a no-op (ctx is None) unless a trace is actually live.
        for i in active:
            rq = self._slots[i].request
            if rq.ctx is not None:
                telemetry.record_span("decode", rq.ctx, t0w, win_dur,
                                      node=self._node, k=k_eff,
                                      active=len(active))
        with self._lock:
            c = self._counters
            c["steps"] += k_eff
            c["decode_tokens"] += len(active) * k_eff
            c["occupancy_sum"] += len(active) * k_eff
            c["peak_occupancy"] = max(c["peak_occupancy"], len(active))
            c["host_syncs"] += 1
            self._ewma_us_tok = us_tok if self._ewma_us_tok == 0.0 \
                else 0.2 * us_tok + 0.8 * self._ewma_us_tok
        for i in active:
            slot = self._slots[i]
            # Slice this row's block to its own stop point: tokens past EOS
            # or the max_new budget were computed speculatively inside the
            # window and are simply dropped (the ring rows they touched are
            # rewritten on the slot's next admission).
            for j in range(k_eff):
                tok = int(toks[row_of[i] if row_of is not None else i, j])
                slot.generated.append(tok)
                slot.t += 1
                if (self._eos is not None and tok == self._eos) \
                        or len(slot.generated) >= slot.request.max_new:
                    self._retire(i)
                    break
        return len(active)

    def _retire(self, i: int) -> None:
        slot = self._slots[i]
        self._slots[i] = None
        self._free.append(i)
        if self._has_paged and self._row_pages[i] is not None:
            # Release the refs and re-point the row at the trash page: the
            # freed row keeps riding the fused window until reused, and its
            # speculative writes must not corrupt reallocated pages. The
            # table is host numpy, so this is a free write, not a jit call.
            self._release_pages(self._row_pages[i])
            self._row_pages[i] = None
            self._pages_tab[i] = 0
        out = np.concatenate([slot.request.prompt,
                              np.asarray(slot.generated, np.int32)])
        with self._lock:
            self._counters["retired"] += 1
            self._counters["generated_tokens"] += len(slot.generated)
        slot.request.future.set_result(out)

    # -- lifecycle -----------------------------------------------------------
    def warmup(self) -> "ServeEngine":
        """Compile every fused-window executable this engine can select
        (the power-of-two K ladder up to ``sync_every`` — the *paged*
        ladder when paging is on, with the page table threaded as a real
        operand) plus, with ``prefill_chunk`` set, the chunk-shaped
        ``prefill_extend`` executable, all against throwaway state, so
        nothing compiles mid-serving. Prompt-length prefill shapes still
        compile on first sight — warm those by submitting representative
        prompts."""
        import jax
        import jax.numpy as jnp
        from repro.models import transformer
        if self._has_paged:
            state = transformer.init_decode_state(
                self._cfg, self._ns, self._Lp, page_size=self._ps,
                num_pages=self._P + 1)
            # Compact engines pick a window width per window (the
            # power-of-two ladder up to num_slots), so warm the whole
            # width x K grid — a mid-run width change must not stall
            # serving on a compile.
            widths = []
            if self._compact:
                w = 1
                while w < self._ns:
                    widths.append(w)
                    w *= 2
            widths.append(self._ns)
            for width in widths:
                pages = jnp.zeros((width, self._n_log), jnp.int32)
                k = 1
                while k <= self._sync:
                    toks = jnp.zeros((width, 1), jnp.int32)
                    t = jnp.zeros((width,), jnp.int32)
                    key = None if self._key is None else jax.random.key(0)
                    out = self._fused(k)(self._params, state, toks, t, key,
                                         pages)
                    state = out[1]
                    jax.block_until_ready(out)
                    k = min(k * 2, self._sync) if k < self._sync else k * 2
        else:
            state = transformer.init_decode_state(self._cfg, self._ns,
                                                  self._Lp)
            toks = jnp.zeros((self._ns, 1), jnp.int32)
            t = jnp.zeros((self._ns,), jnp.int32)
            key = None if self._key is None else jax.random.key(0)
            k = 1
            while k <= self._sync:
                out = self._fused(k)(self._params, state, toks, t, key)
                _, state, toks, t, key = out
                jax.block_until_ready(out)
                k = min(k * 2, self._sync) if k < self._sync else k * 2
        if self._can_chunk:
            st1 = transformer.init_decode_state(self._cfg, 1, self._Lp)
            chunk = jnp.zeros((1, self._chunk), jnp.int32)
            logits, _ = self._extend(self._params, st1, chunk, jnp.int32(0))
            jax.block_until_ready(logits)
        return self

    def start(self) -> "ServeEngine":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="serve-engine")
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.step() == 0:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    def stop(self) -> None:
        """Stop the loop and fail anything still queued or in flight."""
        with self._lock:
            self._closed = True
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        with self._lock:
            swap, self._pending_swap = self._pending_swap, None
        if swap is not None:
            swap[1].set()       # unblock a swap_params caller mid-stop
        err = RuntimeError("engine stopped")
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(err)
        while self._ready:
            req = self._ready.popleft()
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(err)
        if self._pending is not None:
            p, self._pending = self._pending, None
            self._free.append(p.slot)
            if self._has_paged:
                self._release_pages(self._row_pages[p.slot])
                self._row_pages[p.slot] = None
            p.request.future.set_exception(err)
        for i, slot in enumerate(self._slots):
            if slot is not None:
                self._slots[i] = None
                self._free.append(i)
                if self._has_paged:
                    self._release_pages(self._row_pages[i])
                    self._row_pages[i] = None
                slot.request.future.set_exception(err)
        if self._prefix is not None:
            self._prefix.clear(self._decref)

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

    # -- introspection -------------------------------------------------------
    @property
    def alive(self) -> bool:
        """False once the engine has been stopped (or killed): new
        submits and swaps fail — the health signal a serving wrapper
        should report upward."""
        with self._lock:
            return not self._closed

    @property
    def num_slots(self) -> int:
        return self._ns

    @property
    def context_len(self) -> int:
        return self._L

    def reset_stats(self) -> None:
        """Zero the counters (benchmarks: exclude warmup/compile from the
        measured window while keeping the warmed jit caches)."""
        with self._lock:
            for k in self._counters:
                self._counters[k] = 0

    def stats(self) -> dict:
        """Counters + derived occupancy; safe from any thread."""
        with self._lock:
            s = dict(self._counters)
            s["ewma_us_per_token"] = self._ewma_us_tok
        s["num_slots"] = self._ns
        s["free_slots"] = len(self._free)
        s["queue_depth"] = self._queue.qsize() + len(self._ready)
        s["mean_occupancy"] = (s["occupancy_sum"] / s["steps"]
                               if s["steps"] else 0.0)
        s["syncs_per_token"] = (s["host_syncs"] / s["generated_tokens"]
                                if s["generated_tokens"] else 0.0)
        if self._has_paged:
            s["pages_total"] = self._P
            s["pages_free"] = len(self._free_pages)
            s["pages_in_use"] = self._P - len(self._free_pages)
            s["pages_per_request_ewma"] = self._ppr_ewma
            if self._prefix is not None:
                s["prefix_cache"] = self._prefix.stats()
        return s

    def load(self) -> dict:
        """Cheap load report (the routing signal a fabric router uses):
        free KV slots, queued requests, EWMA decode us/token and — in
        paged mode — free pages / expected pages-per-request, so a router
        can score admission headroom in *pages* rather than rows. Safe
        from any thread, no full counter copy."""
        with self._lock:
            ewma = self._ewma_us_tok
            free = len(self._free)
        out = {"num_slots": self._ns, "free_slots": free,
               "queue_depth": self._queue.qsize() + len(self._ready),
               "ewma_us_per_token": ewma}
        if self._has_paged:
            out["pages_total"] = self._P
            out["free_pages"] = len(self._free_pages)
            out["pages_per_request_ewma"] = self._ppr_ewma
            out["prefix_hit_rate"] = (self._prefix.stats()["hit_rate"]
                                      if self._prefix is not None else 0.0)
        return out
