"""Mixtral-style token-choice top-k MoE with dense (GShard) dispatch.

TPU-native formulation: top-k routing builds dispatch/combine tensors and
experts run as stacked einsums — no gather/scatter, fully shardable.
Expert weights are 2-D sharded ``P(None, 'data', 'model')`` (FSDP × TP);
the dispatch einsums induce the all-to-all-equivalent collectives under
SPMD. An auxiliary load-balancing loss (Switch style) is returned to the
trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import shard


def init_moe(cfg: ModelConfig, key) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": layers.init_linear(ks[0], d, e),
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }


def _topk_mask(gates: jax.Array, k: int) -> jax.Array:
    """[T, E] -> 0/1 mask of the top-k experts per token."""
    _, idx = jax.lax.top_k(gates, k)
    return jax.nn.one_hot(idx, gates.shape[-1], dtype=gates.dtype).sum(-2)


# Tokens are routed within groups of at most this many tokens; capacity is
# per-group, so the dispatch one-hot is [.., g, E, C_g] instead of
# [.., S, E, C_S] — at 32k sequence that's an 8× memory difference.
GROUP_TOKENS = 4096


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    B0, S0, D = x.shape
    if S0 > GROUP_TOKENS and S0 % GROUP_TOKENS == 0:
        # GShard grouping: route within fixed-size token groups.
        n = S0 // GROUP_TOKENS
        out, aux = apply_moe(cfg, p,
                             x.reshape(B0 * n, GROUP_TOKENS, D))
        return out.reshape(B0, S0, D), aux

    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    # Capacity per expert (tokens routed above it are dropped — standard).
    C = int(cfg.moe_capacity_factor * K * S / E)
    C = max(C, 1)

    xt = x.reshape(B, S, D)
    logits = layers.apply_linear(p["router"], xt).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    mask = _topk_mask(probs, K)                                # [B,S,E] 0/1
    gates = probs * mask
    # Renormalize the chosen gates (Mixtral renormalizes over top-k).
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)

    # Position of each token within its expert's capacity buffer.
    pos = jnp.cumsum(mask, axis=1) * mask - 1.0                # [B,S,E]
    in_cap = (pos >= 0) & (pos < C)
    gates = jnp.where(in_cap, gates, 0.0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype)  # [B,S,E,C]
    dispatch = pos_oh * in_cap.astype(x.dtype)[..., None]             # [B,S,E,C]
    combine = dispatch * gates.astype(x.dtype)[..., None]             # [B,S,E,C]

    # Dispatch tokens to expert buffers, run experts, combine.
    xe = jnp.einsum("bsec,bsd->becd", dispatch, xt)            # [B,E,C,D]
    xe = shard(xe, "dp", None, None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    h = shard(h, "dp", None, None, "tp")
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("bsec,becd->bsd", combine, ye)              # [B,S,D]

    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    f_e = mask.mean(axis=(0, 1))                               # fraction routed
    p_e = probs.mean(axis=(0, 1))                              # mean router prob
    aux = E * jnp.sum(f_e * p_e) * cfg.router_aux_loss
    return y.reshape(B, S, D), aux
