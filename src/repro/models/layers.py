"""Shared layers: norms, RoPE, MLPs, embeddings. Pure-JAX, dict params."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import shard


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> dict:
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


# Dtype in which norm *tensors* live (hillclimb lever). "float32" (default)
# upcasts the whole [B,S,D] activation; on a TP-sharded residual the
# partitioner then places the feature all-gather in the f32 domain — 2× the
# wire and HBM bytes. "compute" keeps tensor-sized values in the compute
# dtype and does only the reductions (mean/var) in fp32.
NORM_RESIDENT_DTYPE = "float32"


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if NORM_RESIDENT_DTYPE == "float32":
        # Reference path: everything in fp32, output in compute dtype.
        x = x.astype(jnp.float32)
        if cfg.norm == "layernorm":
            x = x - x.mean(-1, keepdims=True)
        var = (x * x).mean(-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + cfg.norm_eps)
        x = x * p["scale"]
        if cfg.norm == "layernorm":
            x = x + p["bias"]
        return x.astype(dt)
    # bf16-resident path: tensor-sized values stay in `dt`; the statistics
    # are still accumulated in fp32 (inputs upcast inside the reduction).
    if cfg.norm == "layernorm":
        mu = x.astype(jnp.float32).mean(-1, keepdims=True)
        x = x - mu.astype(dt)
    var = jnp.square(x.astype(jnp.float32)).mean(-1, keepdims=True)
    inv = jax.lax.rsqrt(var + cfg.norm_eps)
    x = x * inv.astype(dt)
    x = x * p["scale"].astype(dt)
    if cfg.norm == "layernorm":
        x = x + p["bias"].astype(dt)
    return x


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                scale: Optional[float] = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"kernel": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """positions [B, S] (int) -> (sin, cos) each [B, S, head_dim/2], fp32."""
    dh = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, dh/2]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [B, S, H, dh]; rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[:, :, None, :].astype(x.dtype)
    cos = cos[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# MLP (dense; MoE lives in moe.py)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": init_linear(ks[0], d, f, bias=cfg.mlp_bias),
            "w_up": init_linear(ks[1], d, f, bias=cfg.mlp_bias),
            "w_down": init_linear(ks[2], f, d, bias=cfg.mlp_bias,
                                  scale=f ** -0.5),
        }
    return {
        "w_up": init_linear(ks[0], d, f, bias=cfg.mlp_bias),
        "w_down": init_linear(ks[1], f, d, bias=cfg.mlp_bias, scale=f ** -0.5),
    }


def apply_mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(apply_linear(p["w_gate"], x)) * apply_linear(p["w_up"], x)
    else:
        h = jax.nn.gelu(apply_linear(p["w_up"], x))
    h = shard(h, "dp", None, "tp")
    return apply_linear(p["w_down"], h)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    p = {"tokens": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                     jnp.float32) * (cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["head"] = init_linear(ks[1], cfg.d_model, cfg.vocab_size)
    if cfg.conv_pos:
        # HuBERT-style depthwise-ish grouped conv positional embedding.
        w = cfg.conv_pos_width
        g = cfg.conv_pos_groups
        p["conv_pos"] = jax.random.normal(
            ks[2], (w, cfg.d_model // g, cfg.d_model), jnp.float32
        ) * ((w * cfg.d_model // g) ** -0.5)
    return p


def embed_tokens(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    x = p["tokens"].astype(cdtype(cfg))[tokens]
    # The table is vocab-(row-)sharded over the FSDP axis; XLA partitions
    # the gather via its masked-lookup + all-reduce path and the output
    # lands DP-sharded. (Feature-sharded tables + an output constraint
    # trip an XLA SPMD bug: invalid dynamic-slice after partitioning.)
    x = shard(x, "dp", None, None)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def add_conv_pos(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if "conv_pos" not in p:
        return x
    # grouped 1-D conv over sequence, SAME padding.
    pos = jax.lax.conv_general_dilated(
        x, p["conv_pos"].astype(x.dtype),
        window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=cfg.conv_pos_groups)
    return x + jax.nn.gelu(pos)


def lm_logits(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        # The table lives feature-sharded (good for the lookup); reshard it
        # vocab-sharded here so logits come out P(dp, None, tp) from a local
        # matmul — one table-sized collective per step instead of
        # materializing replicated [B,S,V] logits.
        w = shard(p["tokens"], "tp", None)
        return x @ w.astype(x.dtype).T
    return apply_linear(p["head"], x)
