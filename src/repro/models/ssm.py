"""Mamba-1 selective SSM block (Falcon-Mamba).

    x -> in_proj -> (u, z)                u: [B,S,Di], z: gate branch
    u -> causal depthwise conv(K) -> silu
    (Δ, B, C) from u via x_proj/dt_proj;  A = -exp(A_log) [Di,N]
    h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t u_t     (diagonal A ⇒ per-channel)
    y_t = C_t · h_t + D u_t
    out = out_proj(y * silu(z))

Full-sequence mode uses an associative scan over S; decode keeps
(h [B,Di,N], conv tail) as state. FLOPs are dominated by in/out
projections, as in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import shard

# Dtype of the associative-scan elements dA/dBu (hillclimb lever). fp32 is
# the reference; bf16 halves the dominant [B,S,Di,N] HBM traffic of the
# XLA path. The recurrent carry at chunk boundaries stays fp32 either way
# (the Pallas kernel keeps the whole state fp32 in VMEM).
SCAN_DTYPE = "float32"


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba_block(cfg: ModelConfig, key) -> dict:
    d, di, n, r = cfg.d_model, d_inner(cfg), cfg.ssm_state, cfg.ssm_dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": layers.init_linear(ks[0], d, 2 * di),
        "conv1d": jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                  * (cfg.ssm_conv ** -0.5),
        "conv_bias": jnp.zeros((di,), jnp.float32),
        "x_proj": layers.init_linear(ks[2], di, r + 2 * n),
        "dt_proj": layers.init_linear(ks[3], r, di, bias=True),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.init_linear(ks[4], di, d, scale=di ** -0.5),
    }


def _conv1d(p: dict, u: jax.Array, state: jax.Array | None = None):
    K = p["conv1d"].shape[0]
    w = p["conv1d"].astype(u.dtype)
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
        up = jnp.concatenate([pad, u], axis=1)
        new_state = up[:, -(K - 1):, :]
    else:
        up = jnp.concatenate([state.astype(u.dtype), u], axis=1)
        new_state = up[:, -(K - 1):, :]
    out = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(K))
    return out + p["conv_bias"].astype(u.dtype), new_state


def _ssm_params(cfg: ModelConfig, p: dict, u: jax.Array):
    """u [B,S,Di] -> Δ [B,S,Di], B/C [B,S,N] (fp32)."""
    n, r = cfg.ssm_state, cfg.ssm_dt_rank
    dbc = layers.apply_linear(p["x_proj"], u)
    dt, Bc, Cc = jnp.split(dbc, [r, r + n], axis=-1)
    delta = jax.nn.softplus(layers.apply_linear(p["dt_proj"], dt).astype(jnp.float32))
    return delta, Bc.astype(jnp.float32), Cc.astype(jnp.float32)


def selective_scan(cfg: ModelConfig, p: dict, u: jax.Array,
                   h0: jax.Array | None = None):
    """Full-sequence scan. u [B,S,Di] -> (y [B,S,Di], h_S [B,Di,N])."""
    A = -jnp.exp(p["A_log"])                                   # [Di,N]
    delta, Bc, Cc = _ssm_params(cfg, p, u)
    uf = u.astype(jnp.float32)
    sdt = jnp.dtype(SCAN_DTYPE)
    # Discretize: a_t = exp(Δ_t ⊗ A)  [B,S,Di,N];  b_t = Δ_t u_t ⊗ B_t.
    dA = jnp.exp(delta[..., None] * A[None, None]).astype(sdt)
    dBu = ((delta * uf)[..., None] * Bc[:, :, None, :]).astype(sdt)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_cum, h = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    h = h.astype(jnp.float32)
    if h0 is not None:
        h = h + a_cum.astype(jnp.float32) * h0[:, None]
    y = jnp.einsum("bsdn,bsn->bsd", h, Cc)
    y = y + uf * p["D"]
    return y.astype(u.dtype), h[:, -1]


def selective_step(cfg: ModelConfig, p: dict, u: jax.Array, h: jax.Array):
    """One token. u [B,1,Di], h [B,Di,N] -> (y [B,1,Di], h')."""
    A = -jnp.exp(p["A_log"])
    delta, Bc, Cc = _ssm_params(cfg, p, u)
    uf = u.astype(jnp.float32)
    dA = jnp.exp(delta[:, 0, :, None] * A[None])               # [B,Di,N]
    dBu = (delta[:, 0] * uf[:, 0])[..., None] * Bc[:, 0, None, :]
    h_new = dA * h + dBu
    y = jnp.einsum("bdn,bn->bd", h_new, Cc[:, 0])
    y = y + uf[:, 0] * p["D"]
    return y.astype(u.dtype)[:, None], h_new


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    di, n, K = d_inner(cfg), cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di), jnp.float32),
    }


def mamba_state_spec(cfg: ModelConfig, batch: int) -> dict:
    di, n, K = d_inner(cfg), cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jax.ShapeDtypeStruct((batch, di, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, K - 1, di), jnp.float32),
    }


def apply_mamba_block(cfg: ModelConfig, p: dict, x: jax.Array,
                      state: dict | None = None, want_state: bool = False):
    """x [B,S,D] -> [B,S,D]; with state (decode) S must be 1.

    ``want_state=True`` (prefill) returns the final SSM/conv state of a
    full-sequence pass.
    """
    uz = layers.apply_linear(p["in_proj"], x)
    uz = shard(uz, "dp", None, "tp")
    u, z = jnp.split(uz, 2, axis=-1)
    if state is None:
        from repro.models.scan_utils import chunked_recurrence, pick_chunk
        u_raw, conv_tail = _conv1d(p, u)
        u = jax.nn.silu(u_raw)
        h0 = jnp.zeros((x.shape[0], d_inner(cfg), cfg.ssm_state), jnp.float32)
        y, h_last = chunked_recurrence(
            lambda uc, h: selective_scan(cfg, p, uc, h), u, h0,
            chunk=pick_chunk(x.shape[1], 256))
        new_state = None
        if want_state:
            new_state = {"h": h_last.astype(jnp.float32),
                         "conv": conv_tail.astype(jnp.float32)}
    else:
        u, conv_state = _conv1d(p, u, state["conv"])
        u = jax.nn.silu(u)
        y, h_new = selective_step(cfg, p, u, state["h"])
        new_state = {"h": h_new, "conv": conv_state.astype(jnp.float32)}
    out = layers.apply_linear(p["out_proj"], y * jax.nn.silu(z))
    return out, new_state
