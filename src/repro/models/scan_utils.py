"""Chunked linear-recurrence driver.

``associative_scan`` over the full sequence materializes O(S·state)
intermediates (for Mamba that's [B,S,Di,N] — 3.4e13 bytes at train_4k).
Instead we ``lax.scan`` over sequence chunks, carrying the recurrent state
across chunk boundaries and running the log-depth associative scan only
within a chunk. This bounds live memory to one chunk's intermediates and is
the same blocking the Pallas kernels use on TPU (HBM -> VMEM tiles).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

# Roofline cost-probe hook: XLA cost analysis counts while-loop bodies once,
# so probes force a single chunk (no lax.scan) to expose the full
# per-layer FLOPs. Never set in production paths (memory!).
FORCE_SINGLE_CHUNK = False


def chunked_recurrence(seq_fn: Callable, x: jax.Array, init_state,
                       chunk: int = 512):
    """Run ``seq_fn(x_chunk, h0) -> (y_chunk, h_last)`` over S in chunks.

    x: [B, S, ...] with S divisible by ``chunk`` (callers pad if needed).
    Returns (y [B, S, ...], final_state).
    """
    B, S = x.shape[0], x.shape[1]
    if S <= chunk or FORCE_SINGLE_CHUNK:
        return seq_fn(x, init_state)
    if S % chunk:
        raise ValueError(f"seq len {S} not divisible by chunk {chunk}")
    n = S // chunk
    xs = x.reshape(B, n, chunk, *x.shape[2:]).swapaxes(0, 1)  # [n,B,chunk,...]

    def body(h, xc):
        y, h_new = seq_fn(xc, h)
        return h_new, y

    h_last, ys = jax.lax.scan(body, init_state, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, *ys.shape[3:])
    return y, h_last


def pick_chunk(seq_len: int, target: int = 512) -> int:
    """Largest divisor of seq_len that is <= target (>= 1)."""
    c = min(seq_len, target)
    while seq_len % c:
        c -= 1
    return c
