"""GQA self-attention (global / sliding-window / local / bidirectional),
cross-attention, and the single-token decode path against a KV cache.

The XLA einsum path below is the reference data plane used by the dry-run
(Pallas kernels cannot lower for the CPU placeholder backend); the Pallas
flash kernels in ``repro.kernels`` implement the same contract for TPU and
are validated against ``repro.kernels.ref``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import current_ctx, shard

NEG_INF = -2.0 ** 30


def _tp_size() -> int:
    ctx = current_ctx()
    if ctx is None:
        return 1
    axes = ctx.resolve("tp") or ()
    n = 1
    for a in ((axes,) if isinstance(axes, str) else axes):
        n *= ctx.mesh.shape[a]
    return n


def _pad_heads(x: jax.Array, hp: int) -> jax.Array:
    """Zero-pad the head dim (axis 2) to ``hp`` heads."""
    pad = hp - x.shape[2]
    if pad == 0:
        return x
    z = jnp.zeros(x.shape[:2] + (pad, x.shape[3]), x.dtype)
    return jnp.concatenate([x, z], axis=2)


def init_attention(cfg: ModelConfig, key, cross: bool = False) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": layers.init_linear(ks[0], d, h * dh, bias=cfg.qkv_bias),
        "wk": layers.init_linear(ks[1], d, kv * dh, bias=cfg.qkv_bias),
        "wv": layers.init_linear(ks[2], d, kv * dh, bias=cfg.qkv_bias),
        "wo": layers.init_linear(ks[3], h * dh, d, scale=(h * dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
    return p


def _headwise_rms(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def _project_qkv(cfg: ModelConfig, p: dict, xq: jax.Array, xkv: jax.Array):
    B, Sq = xq.shape[:2]
    Skv = xkv.shape[1]
    q = layers.apply_linear(p["wq"], xq).reshape(B, Sq, cfg.num_heads, cfg.head_dim)
    k = layers.apply_linear(p["wk"], xkv).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = layers.apply_linear(p["wv"], xkv).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = _headwise_rms(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = _headwise_rms(k, p["k_norm"]["scale"], cfg.norm_eps)
    return q, k, v


def _chunked_sdpa_map(cfg: ModelConfig, q, k, v, causal: bool,
                      window: Optional[int]) -> jax.Array:
    """Query-chunked attention under lax.map: one chunk's logits live at a
    time. For windowed attention K/V are dynamic-sliced to the reachable
    band (O(S·window) compute); causal full attention keeps full-length K
    per chunk (rectangle, ~2× triangle FLOPs — the Pallas kernel does the
    triangle on TPU)."""
    B, S, H, dh = q.shape
    nc = S // Q_CHUNK
    assert S % Q_CHUNK == 0, (S, Q_CHUNK)
    if window is not None:
        klen = min(S, window + Q_CHUNK)
    else:
        klen = S

    def chunk_fn(i):
        q0 = i * Q_CHUNK
        qc = jax.lax.dynamic_slice_in_dim(q, q0, Q_CHUNK, axis=1)
        if klen == S:
            kc, vc, k0 = k, v, jnp.int32(0)
        else:
            k0 = jnp.maximum(q0 + Q_CHUNK - klen, 0)
            kc = jax.lax.dynamic_slice_in_dim(k, k0, klen, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k0, klen, axis=1)
        q_pos = (q0 + jnp.arange(Q_CHUNK, dtype=jnp.int32))[None]
        k_pos = (k0 + jnp.arange(klen, dtype=jnp.int32))[None]
        bias = _mask_bias(cfg, q_pos, k_pos, causal, window)[:, None]
        return _sdpa(cfg, qc, kc, vc, bias)       # [B, Qc, H, dh]

    out = jax.lax.map(chunk_fn, jnp.arange(nc, dtype=jnp.int32))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)


def _mask_bias(cfg: ModelConfig, q_pos: jax.Array, k_pos: jax.Array,
               causal: bool, window: Optional[int]) -> jax.Array:
    """[.., Sq, Sk] additive mask from absolute positions."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(cfg: ModelConfig, q, k, v, bias) -> jax.Array:
    """q [B,Sq,H,dh], k/v [B,Sk,KV,dh], bias [B,1,Sq,Sk] fp32.

    XLA-path attention: KV heads are expanded to the query-head count and
    the head dim is zero-padded up to a multiple of the TP axis, so logits
    shard as P(dp, tp, None, None) with no exotic 5-D reshards (those push
    the SPMD partitioner onto broken 'last-resort' paths). Pad heads cost
    extra FLOPs for the 12/24/10-head archs — visible in the roofline
    useful-FLOPs ratio; the Pallas kernel keeps true GQA on TPU.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    tp = _tp_size()
    Hp = H + ((-H) % tp)
    q, k, v = _pad_heads(q, Hp), _pad_heads(k, Hp), _pad_heads(v, Hp)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    ldt = jnp.dtype(LOGITS_DTYPE)
    # The dot must EMIT ldt for the bytes win — a downstream astype would
    # still materialize the f32 tensor (MXU accumulation is fp32 either way).
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=ldt)
    logits = logits * jnp.asarray(dh ** -0.5, ldt)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = logits + bias.astype(ldt)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out[:, :, :H, :]


# Query-chunk size for the XLA attention path: bounds the materialized
# [B, H, Qc, S] logits (the Pallas flash kernel replaces this on TPU; the
# chunking here is the same blocking expressed at the XLA level).
Q_CHUNK = 2048

# "map": chunks run under lax.map (a while loop) — structurally sequential,
#        so peak memory is ONE chunk's logits. Production default.
# "unrolled": python loop — XLA's scheduler may overlap chunks (memory grows
#        with chunk count) but FLOPs are visible to cost analysis; used by
#        the roofline probes and small-S paths.
CHUNK_MODE = "map"

# Attention-logits dtype (hillclimb lever): fp32 is the safe default; bf16
# halves the dominant HBM term of the XLA attention path at a bounded
# accuracy cost (softmax max-subtraction keeps exponents in range). The
# Pallas kernel always accumulates fp32 in VMEM, where bandwidth is free.
LOGITS_DTYPE = "float32"


def self_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                   positions: jax.Array, kind: str,
                   return_kv: bool = False):
    """Full-sequence self-attention (train / prefill)."""
    causal = cfg.causal
    window = cfg.window if kind in ("swa", "local") else None
    q, k, v = _project_qkv(cfg, p, x, x)
    if cfg.rope:
        sin, cos = layers.rope_freqs(cfg, positions)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    B, S = x.shape[:2]
    if S <= 2 * Q_CHUNK:
        bias = _mask_bias(cfg, positions, positions, causal, window)[:, None]
        out = _sdpa(cfg, q, k, v, bias)
    elif CHUNK_MODE == "map":
        out = _chunked_sdpa_map(cfg, q, k, v, causal, window)
    else:
        # Unrolled python loop: accurate triangle FLOPs for the roofline
        # probes (K sliced to the reachable band per chunk).
        chunks = []
        for q0 in range(0, S, Q_CHUNK):
            q1 = min(q0 + Q_CHUNK, S)
            k0 = max(0, q0 - window) if window is not None else 0
            k1 = q1 if causal else S
            q_pos = positions[:, q0:q1]
            k_pos = positions[:, k0:k1]
            bias = _mask_bias(cfg, q_pos, k_pos, causal, window)[:, None]
            chunks.append(_sdpa(cfg, q[:, q0:q1],
                                k[:, k0:k1], v[:, k0:k1], bias))
        out = jnp.concatenate(chunks, axis=1)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = layers.apply_linear(p["wo"], out)
    if return_kv:
        return out, (k, v)
    return out


def _shard_cache(x: jax.Array) -> jax.Array:
    """KV-cache sharding: batch over DP; KV heads over TP when divisible,
    else the cache length (decode reduces over L -> psum)."""
    tp = _tp_size()
    if tp > 1 and x.shape[2] % tp == 0:
        return shard(x, "dp", None, "tp", None)
    return shard(x, "dp", "tp", None, None)


def build_cache_from_full(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                          context_len: int, kind: str, dtype) -> dict:
    """Scatter full-sequence K/V (prefill) into the ring-cache layout."""
    B, S = k.shape[:2]
    window = cfg.window if kind in ("swa", "local") else None
    L = min(context_len, window) if window else context_len
    keep = min(S, L)
    pos = jnp.arange(S - keep, S)
    slots = jnp.mod(pos, L)
    ck = jnp.zeros((B, L, cfg.num_kv_heads, cfg.head_dim), dtype)
    cv = jnp.zeros((B, L, cfg.num_kv_heads, cfg.head_dim), dtype)
    ck = ck.at[:, slots].set(k[:, S - keep:].astype(dtype))
    cv = cv.at[:, slots].set(v[:, S - keep:].astype(dtype))
    return {"k": _shard_cache(ck), "v": _shard_cache(cv)}


def cross_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                    memory: jax.Array) -> jax.Array:
    """Cross-attention to frontend embeddings (VLM). No RoPE, no mask."""
    q, k, v = _project_qkv(cfg, p, x, memory)
    B, Sq = x.shape[:2]
    Sk = memory.shape[1]
    bias = jnp.zeros((B, 1, Sq, Sk), jnp.float32)
    out = _sdpa(cfg, q, k, v, bias)
    out = out.reshape(B, Sq, cfg.num_heads * cfg.head_dim)
    return layers.apply_linear(p["wo"], out)


# ---------------------------------------------------------------------------
# Decode path: one new token against a (possibly windowed) KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, context_len: int,
                  kind: str, dtype) -> dict:
    """Cache for one attention layer. SWA/local keep only a window ring."""
    window = cfg.window if kind in ("swa", "local") else None
    L = min(context_len, window) if window else context_len
    shape = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_spec(cfg: ModelConfig, batch: int, context_len: int,
                  kind: str, dtype) -> dict:
    window = cfg.window if kind in ("swa", "local") else None
    L = min(context_len, window) if window else context_len
    shape = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def paged_kv_cache_spec(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype) -> dict:
    """Pooled cache for one full-context attention layer: ``num_pages``
    fixed-size pages shared by every row via a per-row page list. Physical
    page 0 is the trash page by convention (free rows and speculative
    post-retirement writes land there); callers size the pool accordingly."""
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def paged_decode_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                           cache: dict, t: jax.Array, pages: jax.Array,
                           impl: str = "auto") -> tuple[jax.Array, dict]:
    """Single-token decode against a *paged* KV pool (full-context ATTN
    layers only — windowed rings are already footprint-bounded and stay
    flat).

    x [B,1,D]; cache {"k"/"v": [P, ps, KV, dh]} shared pool; ``pages``
    [B, n] int32 maps each row's logical page j to a physical page (the
    engine's device-resident page table). The ring modulus is the padded
    length L = n*ps >= context_len; the engine rejects requests with
    prompt+max_new > context_len, so positions never wrap and the flat
    ring-validity arithmetic carries over unchanged.

    The new token's K/V scatter into physical page ``pages[b, t//ps]`` at
    offset ``t%ps`` — rows whose page-list entry is the trash page
    (free rows, speculative tokens past a reservation) write garbage that
    only garbage reads can see. Returns (attn out [B,1,D], updated cache).
    """
    B = x.shape[0]
    ps = cache["k"].shape[1]
    n = pages.shape[1]
    L = n * ps

    tb = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))

    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    pos_new = tb[:, None]
    if cfg.rope:
        sin, cos = layers.rope_freqs(cfg, pos_new)
        q = layers.apply_rope(q, sin, cos)
        k_new = layers.apply_rope(k_new, sin, cos)

    slot = jnp.mod(tb, L)                                      # [B] logical
    pj = slot // ps
    off = slot % ps
    pid = jnp.take_along_axis(pages, pj[:, None], axis=1)[:, 0]  # [B] physical
    k = cache["k"].at[pid, off].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[pid, off].set(v_new[:, 0].astype(cache["v"].dtype))

    # Same ring-position validity as the flat path, over logical slots.
    idx = jnp.arange(L, dtype=jnp.int32)[None, :]
    k_pos = tb[:, None] - jnp.mod(tb[:, None] - idx, L)
    valid = k_pos >= 0

    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "dense"
    if impl == "flash" and _flash_decode_eligible(cfg):
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.paged_decode_attention(
            q[:, 0], k.astype(q.dtype), v.astype(q.dtype), pages, valid)
        out = out[:, None]                                     # [B,1,H,dh]
    else:
        kg = k[pages].reshape(B, L, *k.shape[2:]).astype(q.dtype)
        vg = v[pages].reshape(B, L, *v.shape[2:]).astype(q.dtype)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        bias = bias[:, None, None, :]                          # [B,1,1,L]
        out = _sdpa_grouped(cfg, q, kg, vg, bias)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return layers.apply_linear(p["wo"], out), {"k": k, "v": v}


def _sdpa_grouped(cfg: ModelConfig, q, k, v, bias) -> jax.Array:
    """GQA attention without KV expansion — decode path.

    One query token means no S² tensors, so the grouped einsum is safe and
    avoids materializing H-times-expanded K/V over the whole cache (which
    costs GQA-ratio × cache bytes in temps). Reduction over the (possibly
    TP-sharded) cache length L becomes a psum.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    logits = logits * (dh ** -0.5)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = logits + bias[:, :, None, :, :]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, dh)


def _flash_decode_eligible(cfg: ModelConfig) -> bool:
    """The flash-decode kernel has no softcap and reduces over the whole
    cache length per core, so it needs an unsharded (tp=1) cache."""
    return not cfg.logit_softcap and _tp_size() == 1


def decode_attention(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                     t: jax.Array, kind: str,
                     impl: str = "auto") -> tuple[jax.Array, dict]:
    """x [B,1,D]; ``t`` is the absolute position of the new token — a
    scalar (all rows in lockstep) or a ``[B]`` vector (continuous batching:
    each cache row advances independently, so slots holding sequences of
    different lengths decode together in one step).

    The cache ring-buffers the last ``L`` tokens (L = full context or the
    SWA window). Slots past a row's own ``t`` are masked invalid by the
    ring-position arithmetic, which is what makes ragged admission (and
    right-padded prefill leftovers in those slots) correct rather than
    attended-to garbage. Returns (attn output [B,1,D], updated cache).

    ``impl`` selects the attention leaf: "dense" is the grouped-einsum XLA
    path; "flash" hands q + the ring ``valid`` mask to the one-HBM-pass
    flash-decode kernel via the ``kernels.ops`` dispatcher (the kernel on
    TPU, the jnp oracle as a native executable elsewhere — same wiring,
    swapped leaf); "auto" picks flash exactly when the kernel would be
    real (TPU) and eligible. Ineligible stacks (softcap, sharded cache)
    silently fall back to dense. Resolved at trace time — executable
    caches must key on it.
    """
    B = x.shape[0]
    L = cache["k"].shape[1]
    window = cfg.window if kind in ("swa", "local") else None

    tb = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))     # per-row t

    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    pos_new = tb[:, None]
    if cfg.rope:
        sin, cos = layers.rope_freqs(cfg, pos_new)
        q = layers.apply_rope(q, sin, cos)
        k_new = layers.apply_rope(k_new, sin, cos)

    # Ring write via mask-select, NOT dynamic_update_slice: a DUS onto the
    # TP-sharded cache-length dim makes the partitioner all-gather the whole
    # cache every layer; the where() is elementwise along L and stays local.
    slot = jnp.mod(tb, L)                                      # [B]
    lane = (jnp.arange(L, dtype=jnp.int32)[None, :, None, None]
            == slot[:, None, None, None])
    k = jnp.where(lane, k_new.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(lane, v_new.astype(cache["v"].dtype), cache["v"])

    # Absolute position of every cache slot given the ring layout: slot i
    # holds the most recent token congruent to i mod L that is <= t.
    idx = jnp.arange(L, dtype=jnp.int32)[None, :]
    k_pos = tb[:, None] - jnp.mod(tb[:, None] - idx, L)        # in (t-L, t]
    valid = k_pos >= 0
    if window is not None:
        valid &= (tb[:, None] - k_pos) < window

    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "dense"
    if impl == "flash" and _flash_decode_eligible(cfg):
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.decode_attention(q[:, 0], k.astype(q.dtype),
                                          v.astype(q.dtype), valid)
        out = out[:, None]                                     # [B,1,H,dh]
    else:
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        bias = bias[:, None, None, :]                          # [B,1,1,L]
        out = _sdpa_grouped(cfg, q, k.astype(q.dtype), v.astype(q.dtype),
                            bias)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return layers.apply_linear(p["wo"], out), {"k": k, "v": v}


def extend_attention(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                     t0: jax.Array, kind: str) -> tuple[jax.Array, dict]:
    """Chunked-prefill attention: extend a ring cache by ``C`` prompt
    tokens at positions ``t0 .. t0+C-1`` in one pass.

    x [B,C,D]; ``t0`` is the chunk's first absolute position (scalar or
    [B]). Returns (attn output [B,C,D], updated cache).

    Queries attend over the *concatenation* of the existing cache slots
    and the chunk's own keys, with per-query position masks — the chunk
    is scattered into the ring only afterwards. Writing first would be
    wrong whenever the ring is full: position ``t0+j`` evicts slot
    ``(t0+j) mod L``, whose old token is still inside the window of every
    query earlier in the chunk (its distance is < L <= window+chunk), so
    a pre-write would attend fresh keys where history should be.
    Requires C <= L so the chunk's slots are distinct.
    """
    B, C = x.shape[:2]
    L = cache["k"].shape[1]
    window = cfg.window if kind in ("swa", "local") else None
    if C > L:
        raise ValueError(f"prefill chunk ({C}) exceeds the cache ring ({L})")

    tb = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (B,))
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    pos = tb[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # [B,C]
    if cfg.rope:
        sin, cos = layers.rope_freqs(cfg, pos)
        q = layers.apply_rope(q, sin, cos)
        k_new = layers.apply_rope(k_new, sin, cos)

    # Absolute position of each existing slot *before* this chunk lands:
    # slot i holds the most recent token congruent to i mod L that is
    # <= t0-1. At t0=0 every k_pos_old is negative -> fully masked, so the
    # first chunk extends cleanly from a zeroed state.
    last = tb[:, None] - 1                                        # [B,1]
    idx = jnp.arange(L, dtype=jnp.int32)[None, :]
    k_pos_old = last - jnp.mod(last - idx, L)                     # [B,L]
    diff_old = pos[:, :, None] - k_pos_old[:, None, :]            # [B,C,L]
    ok_old = jnp.broadcast_to(k_pos_old[:, None, :] >= 0, diff_old.shape)
    if window is not None:
        ok_old &= diff_old < window
    diff_new = pos[:, :, None] - pos[:, None, :]                  # [B,C,C]
    ok_new = diff_new >= 0
    if window is not None:
        ok_new &= diff_new < window
    ok = jnp.concatenate([ok_old, ok_new], axis=-1)               # [B,C,L+C]
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[:, None]

    k_all = jnp.concatenate([cache["k"].astype(q.dtype), k_new], axis=1)
    v_all = jnp.concatenate([cache["v"].astype(q.dtype), v_new], axis=1)
    out = _sdpa(cfg, q, k_all, v_all, bias)
    out = out.reshape(B, C, cfg.num_heads * cfg.head_dim)

    slots = jnp.mod(pos, L)                                       # [B,C]
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    ck = cache["k"].at[bidx, slots].set(k_new.astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slots].set(v_new.astype(cache["v"].dtype))
    return layers.apply_linear(p["wo"], out), {"k": ck, "v": cv}
