"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t)                  (recurrence gate, block-diag)
    i_t = sigmoid(W_x x_t)                  (input gate, block-diag)
    a_t = exp(-c * softplus(Λ) * r_t)       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t²) * (i_t * x_t)

Full-sequence mode uses an associative scan over S (log-depth — TPU
friendly); decode mode is a single state update. The block wraps the
recurrence with in/out projections and a short temporal conv, per Griffin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import shard

_C = 8.0
_MAX_SQRT_GRADIENT = 1000.0


def init_rglru_block(cfg: ModelConfig, key) -> dict:
    d, w, hds = cfg.d_model, cfg.lru_width, cfg.lru_heads
    ks = jax.random.split(key, 7)
    blk = w // hds
    # Λ init so that a ∈ [0.9, 0.999] roughly (Griffin appendix).
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "in_x": layers.init_linear(ks[1], d, w),
        "in_gate": layers.init_linear(ks[2], d, w),
        "conv1d": jax.random.normal(ks[3], (cfg.conv1d_width, w), jnp.float32)
                  * (cfg.conv1d_width ** -0.5),
        "gate_a": jax.random.normal(ks[4], (hds, blk, blk), jnp.float32) * blk ** -0.5,
        "gate_x": jax.random.normal(ks[5], (hds, blk, blk), jnp.float32) * blk ** -0.5,
        "bias_a": jnp.zeros((w,), jnp.float32),
        "bias_x": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": layers.init_linear(ks[6], w, d, scale=w ** -0.5),
    }


def _block_diag(p: dict, which: str, x: jax.Array) -> jax.Array:
    """[B,S,W] through block-diagonal [heads, blk, blk] weights."""
    B, S, W = x.shape
    hds, blk, _ = p[f"gate_{which}"].shape
    xh = x.reshape(B, S, hds, blk)
    y = jnp.einsum("bshi,hij->bshj", xh, p[f"gate_{which}"].astype(x.dtype))
    return y.reshape(B, S, W) + p[f"bias_{which}"].astype(x.dtype)


def _conv1d(p: dict, x: jax.Array, state: jax.Array | None = None):
    """Causal depthwise conv width K. state [B, K-1, W] for decode."""
    K = p["conv1d"].shape[0]
    w = p["conv1d"].astype(x.dtype)
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(K - 1):, :] if K > 1 else None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(K - 1):, :]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out, new_state


def _gates(cfg: ModelConfig, p: dict, x: jax.Array):
    r = jax.nn.sigmoid(_block_diag(p, "a", x).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(p, "x", x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r           # [B,S,W] fp32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None))
    gated = mult * i * x.astype(jnp.float32)
    return a, gated


def rglru_scan(cfg: ModelConfig, p: dict, x: jax.Array,
               h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence RG-LRU recurrence. x [B,S,W] -> (y [B,S,W], h_S)."""
    a, gated = _gates(cfg, p, x)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h + a_cum * h0[:, None, :]
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(cfg: ModelConfig, p: dict, x: jax.Array,
               h: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence. x [B,1,W], h [B,W] fp32."""
    a, gated = _gates(cfg, p, x)
    h_new = a[:, 0] * h + gated[:, 0]
    return h_new.astype(x.dtype)[:, None, :], h_new


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    K = cfg.conv1d_width
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, cfg.lru_width), jnp.float32),
    }


def rglru_state_spec(cfg: ModelConfig, batch: int) -> dict:
    K = cfg.conv1d_width
    return {
        "h": jax.ShapeDtypeStruct((batch, cfg.lru_width), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, K - 1, cfg.lru_width), jnp.float32),
    }


def apply_rglru_block(cfg: ModelConfig, p: dict, x: jax.Array,
                      state: dict | None = None, want_state: bool = False):
    """Griffin recurrent block: gate branch ⊙ GELU branch, then out-proj.

    x [B,S,D] -> [B,S,D]. With ``state`` (decode) S must be 1; returns
    (out, new_state). ``want_state=True`` (prefill) returns the final
    recurrence/conv state of a full-sequence pass.
    """
    from repro.models.scan_utils import chunked_recurrence, pick_chunk

    gate = jax.nn.gelu(layers.apply_linear(p["in_gate"], x))      # [B,S,W]
    xin = layers.apply_linear(p["in_x"], x)                        # [B,S,W]
    xin = shard(xin, "dp", None, "tp")
    if state is None:
        xin_raw = xin
        xin, conv_tail = _conv1d(p, xin)
        h0 = jnp.zeros((x.shape[0], cfg.lru_width), jnp.float32)
        y, h_last = chunked_recurrence(
            lambda xc, h: rglru_scan(cfg, p, xc, h), xin, h0,
            chunk=pick_chunk(x.shape[1]))
        new_state = None
        if want_state:
            new_state = {"h": h_last.astype(jnp.float32),
                         "conv": conv_tail.astype(jnp.float32)}
    else:
        xin, conv_state = _conv1d(p, xin, state["conv"])
        y, h_new = rglru_step(cfg, p, xin, state["h"])
        new_state = {"h": h_new, "conv": conv_state.astype(jnp.float32)}
    out = layers.apply_linear(p["out"], y * gate)
    return out, new_state
