"""Backbone assembly: superblock pattern -> scan over repeats -> LM heads.

The layer stack is ``cfg.pattern`` repeated ``cfg.num_repeats`` times (with
stacked params under ``jax.lax.scan``) plus an unrolled remainder. The same
block functions serve training/prefill (full sequence) and decode (single
token + recurrent/KV state).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, rglru, ssm
from repro.models.config import (ATTN, LOCAL, MAMBA, RGLRU, SWA, XATTN,
                                 ModelConfig)
from repro.sharding import shard

# When num_repeats <= this threshold the repeat loop is unrolled in Python
# instead of lax.scan. The roofline cost-probe sets it (scan/while bodies
# are counted ONCE by XLA cost analysis, so per-layer costs must come from
# unrolled compiles); production configs keep scan for compile-time/HLO-size
# independence from depth.
SCAN_UNROLL_THRESHOLD = 0


def _repeat_blocks(body, carry, stacked_params, extra=None):
    """lax.scan over stacked superblocks, or an unrolled Python loop."""
    length = jax.tree.leaves(stacked_params)[0].shape[0]
    xs = stacked_params if extra is None else (stacked_params, extra)
    if length <= SCAN_UNROLL_THRESHOLD:
        ys = []
        for i in range(length):
            xi = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, xi)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        else:
            ys = None
        return carry, ys
    return jax.lax.scan(body, carry, xs)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, kind: str, key) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm": layers.init_norm(cfg)}
    if kind in (ATTN, SWA, LOCAL, XATTN):
        p["attn"] = attention.init_attention(cfg, ks[0], cross=(kind == XATTN))
        p["mlp_norm"] = layers.init_norm(cfg)
        if cfg.num_experts:
            p["mlp"] = moe.init_moe(cfg, ks[1])
        else:
            p["mlp"] = layers.init_mlp(cfg, ks[1])
    elif kind == RGLRU:
        p["rglru"] = rglru.init_rglru_block(cfg, ks[0])
        p["mlp_norm"] = layers.init_norm(cfg)
        p["mlp"] = layers.init_mlp(cfg, ks[1])
    elif kind == MAMBA:
        p["mamba"] = ssm.init_mamba_block(cfg, ks[0])
    else:
        raise ValueError(kind)
    return p


def _init_superblock(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, len(cfg.pattern))
    return {str(i): _init_block(cfg, kind, ks[i])
            for i, kind in enumerate(cfg.pattern)}


def init_params(cfg: ModelConfig, key) -> dict:
    k_embed, k_blocks, k_tail = jax.random.split(key, 3)
    params: dict[str, Any] = {"embed": layers.init_embed(cfg, k_embed)}
    if cfg.num_repeats:
        rep_keys = jax.random.split(k_blocks, cfg.num_repeats)
        params["blocks"] = jax.vmap(
            lambda k: _init_superblock(cfg, k))(rep_keys)
    if cfg.remainder:
        tail_keys = jax.random.split(k_tail, len(cfg.remainder))
        params["tail"] = {str(i): _init_block(cfg, kind, tail_keys[i])
                          for i, kind in enumerate(cfg.remainder)}
    params["final_norm"] = layers.init_norm(cfg)
    return params


def param_shapes(cfg: ModelConfig) -> Any:
    """Abstract param tree (no allocation) — used by the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.key(0))


# ---------------------------------------------------------------------------
# Full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                 positions: jax.Array, memory: Optional[jax.Array]):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(cfg, p["norm"], x)
    if kind in (ATTN, SWA, LOCAL):
        h = attention.self_attention(cfg, p["attn"], h, positions, kind)
    elif kind == XATTN:
        h = attention.cross_attention(cfg, p["attn"], h, memory)
    elif kind == RGLRU:
        h, _ = rglru.apply_rglru_block(cfg, p["rglru"], h)
    elif kind == MAMBA:
        h, _ = ssm.apply_mamba_block(cfg, p["mamba"], h)
    x = x + h
    x = shard(x, "dp", None, None)
    if kind != MAMBA:
        h = layers.apply_norm(cfg, p["mlp_norm"], x)
        if cfg.num_experts:
            h, aux = moe.apply_moe(cfg, p["mlp"], h)
        else:
            h = layers.apply_mlp(cfg, p["mlp"], h)
        x = x + h
        x = shard(x, "dp", None, None)
    return x, aux


def _apply_superblock(cfg: ModelConfig, p: dict, x: jax.Array,
                      positions: jax.Array, memory: Optional[jax.Array]):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.pattern):
        x, a = _apply_block(cfg, kind, p[str(i)], x, positions, memory)
        aux = aux + a
    return x, aux


def forward(cfg: ModelConfig, params: dict, *,
            tokens: Optional[jax.Array] = None,
            embeddings: Optional[jax.Array] = None,
            memory: Optional[jax.Array] = None,
            remat: bool = False,
            resid_tp: bool = False) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (hidden [B,S,D], aux_loss).

    ``resid_tp`` feature-shards the residual stream at superblock
    boundaries (FSDP+SP): the tensors remat saves for backward shrink by
    the TP width at the cost of per-layer feature all-gathers.
    """
    if embeddings is not None:
        x = embeddings.astype(layers.cdtype(cfg))     # audio frontend stub
    else:
        x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = layers.add_conv_pos(cfg, params["embed"], x)
    resid_spec = ("dp", None, "tp") if resid_tp else ("dp", None, None)
    x = shard(x, *resid_spec)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if memory is not None:
        memory = memory.astype(x.dtype)

    aux_total = jnp.zeros((), jnp.float32)

    def sb_fn(blk_params, h, positions, memory):
        h, a = _apply_superblock(cfg, blk_params, h, positions, memory)
        return shard(h, *resid_spec), a
    if remat:
        sb_fn = jax.checkpoint(
            sb_fn, policy=jax.checkpoint_policies.nothing_saveable)

    if "blocks" in params:
        def body(carry, blk_params):
            h, aux = carry
            h, a = sb_fn(blk_params, h, positions, memory)
            return (h, aux + a), None
        (x, aux_total), _ = _repeat_blocks(body, (x, aux_total),
                                           params["blocks"])

    if "tail" in params:
        for i, kind in enumerate(cfg.remainder):
            x, a = _apply_block(cfg, kind, params["tail"][str(i)], x,
                                positions, memory)
            aux_total = aux_total + a

    x = layers.apply_norm(cfg, params["final_norm"], x)
    return x, aux_total


def logits_from_hidden(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    logits = layers.lm_logits(cfg, params["embed"], x)
    return shard(logits, "dp", None, "tp")


def cross_entropy(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Vocab-sharding-friendly CE: one-hot contraction, no gather."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    true_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - true_logit
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            remat: bool = False, resid_tp: bool = False
            ) -> tuple[jax.Array, dict]:
    """Language-model / masked-prediction loss over one (micro)batch."""
    hidden, aux = forward(
        cfg, params,
        tokens=batch.get("tokens"),
        embeddings=batch.get("embeddings"),
        memory=batch.get("image_embeds"),
        remat=remat, resid_tp=resid_tp)
    logits = logits_from_hidden(cfg, params, hidden)
    if cfg.causal and "targets" not in batch:
        # Next-token prediction: shift within the provided sequence.
        ce = cross_entropy(cfg, logits[:, :-1], batch["labels"][:, 1:],
                           batch.get("mask")[:, 1:] if batch.get("mask")
                           is not None else None)
    else:
        # Encoder (HuBERT): predict per-position targets at masked frames.
        ce = cross_entropy(cfg, logits, batch["targets"], batch.get("mask"))
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill: full-sequence pass that also builds the decode state
# ---------------------------------------------------------------------------

def _prefill_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                   positions: jax.Array, memory: Optional[jax.Array],
                   context_len: int, cache_dtype):
    """Like _apply_block but also returns the block's decode state."""
    h = layers.apply_norm(cfg, p["norm"], x)
    if kind in (ATTN, SWA, LOCAL):
        h, (k, v) = attention.self_attention(cfg, p["attn"], h, positions,
                                             kind, return_kv=True)
        state = attention.build_cache_from_full(cfg, k, v, context_len, kind,
                                                cache_dtype)
    elif kind == XATTN:
        h = attention.cross_attention(cfg, p["attn"], h, memory)
        _, k_mem, v_mem = attention._project_qkv(cfg, p["attn"],
                                                 h[:, :1], memory)
        state = {"k_mem": k_mem.astype(cache_dtype),
                 "v_mem": v_mem.astype(cache_dtype)}
    elif kind == RGLRU:
        h, state = rglru.apply_rglru_block(cfg, p["rglru"], h,
                                           want_state=True)
    elif kind == MAMBA:
        h, state = ssm.apply_mamba_block(cfg, p["mamba"], h, want_state=True)
    else:
        raise ValueError(kind)
    x = x + h
    if kind != MAMBA:
        h = layers.apply_norm(cfg, p["mlp_norm"], x)
        if cfg.num_experts:
            h, _ = moe.apply_moe(cfg, p["mlp"], h)
        else:
            h = layers.apply_mlp(cfg, p["mlp"], h)
        x = x + h
    return x, state


def prefill(cfg: ModelConfig, params: dict, *, tokens=None, memory=None,
            embeddings=None, context_len: Optional[int] = None,
            cache_dtype=jnp.bfloat16):
    """Full-sequence forward that also builds the decode state.

    Returns (logits [B,S,V], decode_state positioned at t = S).
    """
    if embeddings is not None:
        x = embeddings.astype(layers.cdtype(cfg))
    else:
        x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = layers.add_conv_pos(cfg, params["embed"], x)
    x = shard(x, "dp", None, None)
    B, S = x.shape[:2]
    context_len = context_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if memory is not None:
        memory = memory.astype(x.dtype)

    state: dict[str, Any] = {}
    if "blocks" in params:
        def body(h, blk_params):
            blk_state = {}
            for i, kind in enumerate(cfg.pattern):
                h, s = _prefill_block(cfg, kind, blk_params[str(i)], h,
                                      positions, memory, context_len,
                                      cache_dtype)
                blk_state[str(i)] = s
            return h, blk_state
        x, state["blocks"] = _repeat_blocks(body, x, params["blocks"])

    if "tail" in params:
        state["tail"] = {}
        for i, kind in enumerate(cfg.remainder):
            x, s = _prefill_block(cfg, kind, params["tail"][str(i)], x,
                                  positions, memory, context_len, cache_dtype)
            state["tail"][str(i)] = s

    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.lm_logits(cfg, params["embed"], x)
    return shard(logits, "dp", None, "tp"), state


# ---------------------------------------------------------------------------
# Decode: single-token step with per-layer state
# ---------------------------------------------------------------------------

def _block_state_spec(cfg: ModelConfig, kind: str, batch: int,
                      context_len: int, dtype,
                      page_size: Optional[int] = None,
                      num_pages: Optional[int] = None) -> dict:
    if kind == ATTN and page_size is not None:
        return attention.paged_kv_cache_spec(cfg, num_pages, page_size,
                                             dtype)
    if kind in (ATTN, SWA, LOCAL):
        return attention.kv_cache_spec(cfg, batch, context_len, kind, dtype)
    if kind == XATTN:
        shape = (batch, cfg.frontend_tokens, cfg.num_kv_heads, cfg.head_dim)
        return {"k_mem": jax.ShapeDtypeStruct(shape, dtype),
                "v_mem": jax.ShapeDtypeStruct(shape, dtype)}
    if kind == RGLRU:
        return rglru.rglru_state_spec(cfg, batch)
    if kind == MAMBA:
        return ssm.mamba_state_spec(cfg, batch)
    raise ValueError(kind)


def decode_state_spec(cfg: ModelConfig, batch: int, context_len: int,
                      dtype=jnp.bfloat16, page_size: Optional[int] = None,
                      num_pages: Optional[int] = None) -> dict:
    """Abstract decode-state tree matching decode_step's expectations.

    With ``page_size``/``num_pages`` set, full-context ATTN layers swap
    their per-row ``[batch, L]`` rings for one shared ``[num_pages,
    page_size]`` pool addressed through a per-row page table (see
    ``decode_step``'s ``pages``). Windowed rings (SWA/LOCAL) and
    recurrent/XATTN state stay per-row: their footprint is already
    bounded, so paging buys nothing there.
    """
    def stack(spec_fn):
        one = spec_fn()
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_repeats,) + s.shape,
                                           s.dtype), one)

    state: dict[str, Any] = {}
    if cfg.num_repeats:
        state["blocks"] = {
            str(i): stack(functools.partial(
                _block_state_spec, cfg, kind, batch, context_len, dtype,
                page_size, num_pages))
            for i, kind in enumerate(cfg.pattern)}
    if cfg.remainder:
        state["tail"] = {
            str(i): _block_state_spec(cfg, kind, batch, context_len, dtype,
                                      page_size, num_pages)
            for i, kind in enumerate(cfg.remainder)}
    return state


def init_decode_state(cfg: ModelConfig, batch: int, context_len: int,
                      dtype=jnp.bfloat16, page_size: Optional[int] = None,
                      num_pages: Optional[int] = None) -> dict:
    spec = decode_state_spec(cfg, batch, context_len, dtype, page_size,
                             num_pages)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def write_decode_slot(cfg: ModelConfig, state: dict, slot_state: dict,
                      index) -> dict:
    """Write a batch-1 decode-state tree into row ``index`` of a batched one.

    ``state`` is the engine's slotted cache (``init_decode_state`` with
    batch = num_slots); ``slot_state`` comes from ``prefill`` over a
    ``[1, S]`` prompt with the same ``context_len``. Leaves under "blocks"
    carry the stacked repeat dim first (batch is axis 1); "tail" leaves
    are batch-leading (axis 0). ``index`` may be traced, so a jitted
    wrapper (ideally donating ``state``) admits a request into a free slot
    without touching the other rows.
    """
    def _write(axis):
        def f(dst, src):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), index, axis=axis)
        return f

    out: dict[str, Any] = {}
    if "blocks" in state:
        out["blocks"] = jax.tree.map(_write(1), state["blocks"],
                                     slot_state["blocks"])
    if "tail" in state:
        out["tail"] = jax.tree.map(_write(0), state["tail"],
                                   slot_state["tail"])
    return out


def _paged_leaf_write(dst: jax.Array, src: jax.Array, row_pages: jax.Array,
                      start_page: jax.Array, page_size: int,
                      page_axis: int) -> jax.Array:
    """Scatter a B=1 flat cache leaf into the shared page pool.

    ``dst`` has physical pages on ``page_axis``; ``src`` is the flat leaf
    with its batch-1 axis at ``page_axis`` and the L_pad sequence right
    after it, so merging that axis with a ``[n_log, page_size]`` split of
    the sequence gives one update block per logical page — the whole row
    lands in a single gather + scatter instead of a per-page
    dynamic-update chain. Logical pages below ``start_page`` are *shared*
    (copy-on-write prefix pages another owner may also read): their pool
    content is rewritten with itself, so the write is a no-op there
    without a traced-shape branch.
    """
    n_log = row_pages.shape[0]
    seq_axis = page_axis + 1
    shape = (src.shape[:page_axis] + (n_log, page_size)
             + src.shape[seq_axis + 1:])
    sp = src.reshape(shape).astype(dst.dtype)       # batch-1 axis -> pages
    cur = jnp.take(dst, row_pages, axis=page_axis)
    keep = jnp.arange(n_log, dtype=jnp.int32) >= start_page
    kshape = ((1,) * page_axis + (n_log,)
              + (1,) * (sp.ndim - page_axis - 1))
    upd = jnp.where(keep.reshape(kshape), sp, cur)
    if page_axis == 0:
        return dst.at[row_pages].set(upd)
    return dst.at[:, row_pages].set(upd)            # stacked repeat leads


def write_paged_slot(cfg: ModelConfig, state: dict, slot_state: dict,
                     index, row_pages: jax.Array, start_page,
                     page_size: int) -> dict:
    """Paged counterpart of ``write_decode_slot``: land a B=1 prefill
    state into row ``index``, scattering full-context ATTN leaves into the
    shared page pool through the row's page list.

    ``row_pages`` [n_log] int32 maps logical page j -> physical page;
    entries past the row's reservation point at the trash page (0), whose
    content only trash reads see. ``start_page`` (traced scalar) is the
    count of leading *shared* prefix pages: those already hold exactly the
    prefill content being written, so they are skipped (copy-on-write —
    the pool rows other owners read are never touched). Non-ATTN leaves
    (windowed rings, recurrent state) write per-row exactly as
    ``write_decode_slot`` does.
    """
    start_page = jnp.asarray(start_page, jnp.int32)

    def _write_kind(kind: str, dst, src, axis: int):
        if kind == ATTN:
            return jax.tree.map(
                lambda d, s: _paged_leaf_write(d, s, row_pages, start_page,
                                               page_size, axis), dst, src)
        return jax.tree.map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), index, axis=axis), dst, src)

    out: dict[str, Any] = {}
    if "blocks" in state:
        out["blocks"] = {
            str(i): _write_kind(kind, state["blocks"][str(i)],
                                slot_state["blocks"][str(i)], 1)
            for i, kind in enumerate(cfg.pattern)}
    if "tail" in state:
        out["tail"] = {
            str(i): _write_kind(kind, state["tail"][str(i)],
                                slot_state["tail"][str(i)], 0)
            for i, kind in enumerate(cfg.remainder)}
    return out


def gather_paged_slot(cfg: ModelConfig, state: dict, index,
                      row_pages: jax.Array, page_size: int) -> dict:
    """Materialize row ``index`` of a paged decode state as a B=1 *flat*
    state (the shape ``prefill_extend`` consumes): ATTN leaves gather the
    row's page list into its logical [1, L_pad] cache view; other leaves
    slice the row. Used on a prefix-cache hit — the gathered view holds
    the shared prefix K/V, the suffix extends it, and ``write_paged_slot``
    (start_page = shared count) scatters only the owned pages back.
    """
    n_log = row_pages.shape[0]

    def _gather_kind(kind: str, leaf, axis: int):
        if kind == ATTN:
            def g(pool):
                out = jnp.take(pool, row_pages, axis=axis)
                shape = (pool.shape[:axis] + (1, n_log * page_size)
                         + pool.shape[axis + 2:])
                return out.reshape(shape)
            return jax.tree.map(g, leaf)
        return jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, index, 1, axis=axis),
            leaf)

    out: dict[str, Any] = {}
    if "blocks" in state:
        out["blocks"] = {
            str(i): _gather_kind(kind, state["blocks"][str(i)], 1)
            for i, kind in enumerate(cfg.pattern)}
    if "tail" in state:
        out["tail"] = {
            str(i): _gather_kind(kind, state["tail"][str(i)], 0)
            for i, kind in enumerate(cfg.remainder)}
    return out


def paged_window_view(cfg: ModelConfig, state: dict,
                      pages: jax.Array) -> dict:
    """Gather a paged decode state into the equivalent flat per-row view.

    Full-context ATTN pool leaves ([..., P+1, ps, KV, dh]) become the
    flat rings decode_step's non-paged path expects ([..., B, L_pad, KV,
    dh], L_pad = n_log * page_size) by walking each row's page list;
    every other leaf is already per-row and passes through untouched.
    The page table is invariant inside a fused decode window, so doing
    this ONCE per window — instead of re-gathering the pool inside every
    scan step, as the paged attention path must — is what lets the paged
    engine pay ~flat per-step cost; ``paged_window_scatter`` lands the
    window's writes back in the pool afterwards.
    """
    B, n_log = pages.shape

    def _gather_kind(kind: str, leaf, axis: int):
        if kind != ATTN:
            return leaf

        def g(pool):
            ps = pool.shape[axis + 1]
            out = jnp.take(pool, pages, axis=axis)
            shape = (pool.shape[:axis] + (B, n_log * ps)
                     + pool.shape[axis + 2:])
            return out.reshape(shape)
        return jax.tree.map(g, leaf)

    out: dict[str, Any] = {}
    if "blocks" in state:
        out["blocks"] = {
            str(i): _gather_kind(kind, state["blocks"][str(i)], 1)
            for i, kind in enumerate(cfg.pattern)}
    if "tail" in state:
        out["tail"] = {
            str(i): _gather_kind(kind, state["tail"][str(i)], 0)
            for i, kind in enumerate(cfg.remainder)}
    return out


def paged_window_scatter(cfg: ModelConfig, state: dict, flat: dict,
                         pages: jax.Array, t0: jax.Array,
                         steps: int) -> dict:
    """Inverse of ``paged_window_view`` after a ``steps``-long window.

    Decode positions ``t0[b] .. t0[b]+steps-1`` land in at most
    ``1 + ceil((steps-1)/ps)`` consecutive logical pages per row, so only
    those pages scatter back into the pool — everything else in the flat
    view is byte-identical to what the gather read. Pages inside the
    static bound that the window did not actually reach get identity
    writes (their flat content IS the pool content), which is what keeps
    shared copy-on-write prefix pages safe: a row's decode positions
    start at its prompt end, past every fully-covered shared page, so
    real writes only ever land in owned (or trash) pages. Rows whose
    table is all trash (free slots) dogpile page 0 — undefined winner,
    read by nobody. Non-ATTN leaves are per-row state the scan already
    updated in place; they pass through from the flat tree.
    """
    B, n_log = pages.shape
    t0 = jnp.asarray(t0, jnp.int32)
    if t0.ndim == 0:
        t0 = jnp.full((B,), t0)

    def _scatter_kind(kind: str, pool_leaf, flat_leaf, axis: int):
        if kind != ATTN:
            return flat_leaf

        def s(pool, fl):
            ps = pool.shape[axis + 1]
            L = n_log * ps
            ntouch = min(n_log, 1 + (max(steps - 1, 0) + ps - 1) // ps)
            j0 = (t0 % L) // ps
            jj = (j0[:, None]
                  + jnp.arange(ntouch, dtype=jnp.int32)[None, :]) % n_log
            pid = jnp.take_along_axis(pages, jj, axis=1)        # [B, C]
            shape = (fl.shape[:axis] + (B, n_log, ps)
                     + fl.shape[axis + 2:])
            fr = fl.reshape(shape)
            bb = jnp.arange(B, dtype=jnp.int32)[:, None]
            if axis == 0:
                return pool.at[pid].set(fr[bb, jj].astype(pool.dtype))
            return pool.at[:, pid].set(fr[:, bb, jj].astype(pool.dtype))
        return jax.tree.map(s, pool_leaf, flat_leaf)

    out: dict[str, Any] = {}
    if "blocks" in state:
        out["blocks"] = {
            str(i): _scatter_kind(kind, state["blocks"][str(i)],
                                  flat["blocks"][str(i)], 1)
            for i, kind in enumerate(cfg.pattern)}
    if "tail" in state:
        out["tail"] = {
            str(i): _scatter_kind(kind, state["tail"][str(i)],
                                  flat["tail"][str(i)], 0)
            for i, kind in enumerate(cfg.remainder)}
    return out


def _decode_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                  state: dict, t: jax.Array, attn_impl: str = "auto",
                  pages: Optional[jax.Array] = None):
    h = layers.apply_norm(cfg, p["norm"], x)
    if kind == ATTN and pages is not None:
        h, state = attention.paged_decode_attention(cfg, p["attn"], h,
                                                    state, t, pages,
                                                    impl=attn_impl)
    elif kind in (ATTN, SWA, LOCAL):
        h, state = attention.decode_attention(cfg, p["attn"], h, state, t,
                                              kind, impl=attn_impl)
    elif kind == XATTN:
        # Cross K/V are precomputed once (prefill); just attend.
        q, _, _ = attention._project_qkv(cfg, p["attn"], h, h[:, :1])
        B = x.shape[0]
        Sk = state["k_mem"].shape[1]
        bias = jnp.zeros((B, 1, 1, Sk), jnp.float32)
        out = attention._sdpa_grouped(cfg, q, state["k_mem"].astype(q.dtype),
                                      state["v_mem"].astype(q.dtype), bias)
        out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
        h = layers.apply_linear(p["attn"]["wo"], out)
    elif kind == RGLRU:
        h, state = rglru.apply_rglru_block(cfg, p["rglru"], h, state)
    elif kind == MAMBA:
        h, state = ssm.apply_mamba_block(cfg, p["mamba"], h, state)
    x = x + h
    if kind != MAMBA:
        h = layers.apply_norm(cfg, p["mlp_norm"], x)
        if cfg.num_experts:
            h, _ = moe.apply_moe(cfg, p["mlp"], h)
        else:
            h = layers.apply_mlp(cfg, p["mlp"], h)
        x = x + h
    return x, state


def decode_step(cfg: ModelConfig, params: dict, state: dict,
                tokens: jax.Array, t: jax.Array, attn_impl: str = "auto",
                pages: Optional[jax.Array] = None):
    """One decode step. tokens [B,1] int32; t = absolute position — scalar
    (lockstep batch) or ``[B]`` vector (continuous batching / ragged rows,
    each cache row at its own position).

    ``attn_impl`` ("auto" | "dense" | "flash") picks the attention leaf
    for every ATTN/SWA/LOCAL block (see attention.decode_attention); it
    is static config resolved at trace time, so executable caches must
    key on it. With ``pages`` ([B, n_log] int32 page table), full-context
    ATTN layers read their state as a shared page pool (see
    ``decode_state_spec``'s paged mode) — the table is loop-invariant
    across the repeat scan, so it rides in by closure, not as a carry.
    Returns (logits [B,1,V], new_state).
    """
    x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = shard(x, "dp", None, None)
    new_state: dict[str, Any] = {}

    if "blocks" in params:
        def body(h, inputs):
            blk_params, blk_state = inputs
            new_blk_state = {}
            for i, kind in enumerate(cfg.pattern):
                h, s = _decode_block(cfg, kind, blk_params[str(i)], h,
                                     blk_state[str(i)], t, attn_impl,
                                     pages)
                new_blk_state[str(i)] = s
            return h, new_blk_state
        x, new_state["blocks"] = _repeat_blocks(
            body, x, params["blocks"], extra=state["blocks"])

    if "tail" in params:
        new_state["tail"] = {}
        for i, kind in enumerate(cfg.remainder):
            x, s = _decode_block(cfg, kind, params["tail"][str(i)], x,
                                 state["tail"][str(i)], t, attn_impl,
                                 pages)
            new_state["tail"][str(i)] = s

    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.lm_logits(cfg, params["embed"], x)
    return shard(logits, "dp", None, "tp"), new_state


# ---------------------------------------------------------------------------
# Chunked prefill: extend a decode state by a block of prompt tokens
# ---------------------------------------------------------------------------

def _extend_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                  state: dict, t0: jax.Array):
    if kind not in (ATTN, SWA, LOCAL):
        raise ValueError(
            f"chunked prefill needs an attention-only stack, got {kind!r}")
    h = layers.apply_norm(cfg, p["norm"], x)
    h, state = attention.extend_attention(cfg, p["attn"], h, state, t0, kind)
    x = x + h
    h = layers.apply_norm(cfg, p["mlp_norm"], x)
    if cfg.num_experts:
        h, _ = moe.apply_moe(cfg, p["mlp"], h)
    else:
        h = layers.apply_mlp(cfg, p["mlp"], h)
    x = x + h
    return x, state


def prefill_extend(cfg: ModelConfig, params: dict, state: dict,
                   tokens: jax.Array, t0: jax.Array):
    """Advance a decode state by a chunk of ``C`` prompt tokens.

    tokens [B,C] int32 at absolute positions ``t0 .. t0+C-1``; ``state``
    comes from ``init_decode_state`` (first chunk: positions mask every
    zeroed slot invalid) or a previous ``prefill_extend``. Attention-only
    stacks: recurrent blocks (RG-LRU / Mamba) would need their own chunk
    scan, and XATTN needs frontend memory — the engine gates those to the
    monolithic exact-length prefill.

    Returns (last-position logits [B,1,V], new_state positioned at
    ``t0 + C``) — feed the next chunk at ``t0 + C``, or sample the first
    generated token from the logits after the final chunk.
    """
    x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = shard(x, "dp", None, None)
    new_state: dict[str, Any] = {}

    if "blocks" in params:
        def body(h, inputs):
            blk_params, blk_state = inputs
            new_blk_state = {}
            for i, kind in enumerate(cfg.pattern):
                h, s = _extend_block(cfg, kind, blk_params[str(i)], h,
                                     blk_state[str(i)], t0)
                new_blk_state[str(i)] = s
            return h, new_blk_state
        x, new_state["blocks"] = _repeat_blocks(
            body, x, params["blocks"], extra=state["blocks"])

    if "tail" in params:
        new_state["tail"] = {}
        for i, kind in enumerate(cfg.remainder):
            x, s = _extend_block(cfg, kind, params["tail"][str(i)], x,
                                 state["tail"][str(i)], t0)
            new_state["tail"][str(i)] = s

    x = layers.apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = layers.lm_logits(cfg, params["embed"], x)
    return shard(logits, "dp", None, "tp"), new_state
