"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense GQA transformers, MoE (Mixtral), hybrid
recurrent (RecurrentGemma RG-LRU + local attention), pure SSM (Mamba-1),
encoder-only audio (HuBERT) and cross-attention VLM (Llama-3.2-Vision)
backbones. Layer stacks are described as a repeating *superblock pattern*
plus a remainder, so depth runs under ``jax.lax.scan`` with stacked params
(compile time and HLO size independent of depth).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Block kinds usable in a superblock pattern.
ATTN = "attn"          # global self-attention (+ MLP)
SWA = "swa"            # sliding-window self-attention (+ MLP)
LOCAL = "local"        # local attention, RecurrentGemma style (+ MLP)
XATTN = "xattn"        # cross-attention to frontend embeddings (+ MLP)
RGLRU = "rglru"        # RG-LRU recurrent block (+ MLP)
MAMBA = "mamba"        # Mamba-1 block (no separate MLP)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- layer pattern -----------------------------------------------------
    # The layer stack is `pattern` repeated, then `remainder` extra entries.
    # Default: homogeneous causal attention.
    pattern: tuple[str, ...] = (ATTN,)

    # --- attention -----------------------------------------------------------
    head_dim: Optional[int] = None           # default d_model // num_heads
    causal: bool = True                      # False => encoder-only
    qkv_bias: bool = False                   # qwen2 / starcoder2
    qk_norm: bool = False                    # qwen3
    rope: bool = True
    rope_theta: float = 10_000.0
    window: Optional[int] = None             # SWA / local-attn window
    logit_softcap: Optional[float] = None

    # --- MLP -----------------------------------------------------------------
    mlp: str = "swiglu"                      # swiglu | geglu | gelu
    mlp_bias: bool = False

    # --- norms / embeddings ----------------------------------------------------
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False                # multiply embed by sqrt(d_model)
    conv_pos: bool = False                   # HuBERT conv positional embedding
    conv_pos_width: int = 128
    conv_pos_groups: int = 16

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss: float = 0.02

    # --- SSM (Mamba-1) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                     # default ceil(d_model / 16)

    # --- RG-LRU (RecurrentGemma) --------------------------------------------------
    lru_width: int = 0
    lru_heads: int = 8                       # block-diagonal gate heads
    conv1d_width: int = 4

    # --- VLM / audio frontends (stubs feed precomputed embeddings) -------------
    cross_attn_every: int = 0                # kept for docs; pattern encodes it
    frontend_tokens: int = 0                 # image patches / audio frames

    # --- dtypes ------------------------------------------------------------------
    param_dtype: str = "float32"             # master weights
    compute_dtype: str = "bfloat16"

    # ---------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_state and not self.ssm_dt_rank:
            object.__setattr__(self, "ssm_dt_rank",
                               int(math.ceil(self.d_model / 16)))
        if self.num_layers % len(self.pattern) and self.family == "moe":
            raise ValueError("MoE stacks must tile the pattern exactly")

    # --- pattern helpers -----------------------------------------------------
    @property
    def num_repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def remainder(self) -> tuple[str, ...]:
        return self.pattern[: self.num_layers % len(self.pattern)]

    @property
    def attention_free(self) -> bool:
        kinds = set(self.pattern) | set(self.remainder)
        return not (kinds & {ATTN, SWA, LOCAL, XATTN})

    @property
    def decode_supported(self) -> bool:
        return self.causal  # encoder-only models have no autoregressive step

    @property
    def subquadratic(self) -> bool:
        """True if per-token serve cost is O(1) in context length."""
        kinds = set(self.pattern) | set(self.remainder)
        return ATTN not in kinds and XATTN not in kinds

    # --- parameter counting (for MODEL_FLOPS = 6·N·D) -------------------------
    def _block_params(self, kind: str) -> int:
        d, f = self.d_model, self.d_ff
        h, kv, dh = self.num_heads, self.num_kv_heads, (self.head_dim or 0)
        n = 0
        if kind in (ATTN, SWA, LOCAL, XATTN):
            n += d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d  # q k v o
            if self.qkv_bias:
                n += (h + 2 * kv) * dh
            if self.qk_norm:
                n += 2 * dh
            n += d  # pre-norm
            if kind == XATTN:
                n += d  # kv norm (stub-side embeddings are normed)
            # MLP attached to attention blocks
            n += self._mlp_params()
        elif kind == RGLRU:
            w = self.lru_width
            n += 2 * d * w + w * d          # in-proj x2, out-proj
            n += self.conv1d_width * w      # temporal conv
            n += 2 * w * w // self.lru_heads + 2 * w  # block-diag gate projections
            n += w                          # Lambda
            n += d                          # pre-norm
            n += self._mlp_params()
        elif kind == MAMBA:
            di = self.ssm_expand * self.d_model
            dt = self.ssm_dt_rank
            s = self.ssm_state
            n += d * 2 * di                 # in_proj
            n += self.ssm_conv * di         # depthwise conv
            n += di * (dt + 2 * s)          # x_proj
            n += dt * di + di               # dt_proj
            n += di * s + di                # A_log, D
            n += di * d                     # out_proj
            n += d                          # pre-norm
        else:
            raise ValueError(kind)
        return n

    def _mlp_params(self) -> int:
        d, f = self.d_model, self.d_ff
        if self.num_experts:
            per = 3 * d * f  # swiglu experts
            return self.num_experts * per + d * self.num_experts + d  # + router + norm
        if self.mlp in ("swiglu", "geglu"):
            n = 3 * d * f
        else:
            n = 2 * d * f + (f + d if self.mlp_bias else 0)
        return n + d  # + pre-norm

    def _active_mlp_params(self) -> int:
        if not self.num_experts:
            return self._mlp_params()
        d, f = self.d_model, self.d_ff
        return self.experts_per_token * 3 * d * f + d * self.num_experts + d

    def param_count(self) -> int:
        layers = list(self.pattern) * self.num_repeats + list(self.remainder)
        n = sum(self._block_params(k) for k in layers)
        n += self.vocab_size * self.d_model          # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model      # separate output head
        n += self.d_model                            # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        layers = list(self.pattern) * self.num_repeats + list(self.remainder)
        n = 0
        for k in layers:
            full = self._block_params(k)
            n += full - self._mlp_params() + self._active_mlp_params()
        n += self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self.d_model
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    # decode: seq_len is the KV-cache / context length; one new token is fed.


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicability(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a human-readable skip reason."""
    if shape.kind == "decode" and not cfg.decode_supported:
        return "encoder-only architecture: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full global attention: 524k dense KV cache is O(seq) memory "
                "and per-token compute; shape reserved for sub-quadratic archs")
    return None
