"""Batched LM serving as a Launchpad program.

    frontend clients (CourierNode × N)
      -> batcher (CourierNode: request queue -> batched generate)
      -> model server (MeshWorkerNode: prefill + decode over its mesh)

The batcher implements continuous request coalescing: it drains up to
``max_batch`` queued prompts, pads them to one batch, and runs
prefill+decode once — the standard serving pattern expressed as Launchpad
topology.

    PYTHONPATH=src python -m repro.launch.serve --requests 12
"""

from __future__ import annotations

import argparse
import queue
import threading
import time

import numpy as np

from repro import configs, core as lp
from repro.models.config import ModelConfig
from repro.serve import decode as serve_lib


class ModelServer:
    """Holds params; serves batched generate() on its mesh.

    ``prompts`` arrives over courier as a read-only array that may alias
    shared transport memory (the shm slot pool) — ``jnp.asarray`` device-
    puts straight from that view, so the Batcher -> ModelServer hop adds
    no extra host copy, and the slot frees when this call returns.
    """

    def __init__(self, model_cfg: ModelConfig, max_new: int = 8, mesh=None):
        import jax
        from repro.models import transformer
        self._cfg = model_cfg
        self._max_new = max_new
        self._params = transformer.init_params(model_cfg, jax.random.key(0))

    def generate(self, prompts):
        import jax.numpy as jnp
        toks = jnp.asarray(np.asarray(prompts, np.int32))
        out = serve_lib.generate(self._cfg, self._params, toks,
                                 max_new=self._max_new,
                                 context_len=toks.shape[1] + self._max_new)
        return np.asarray(out)


class Batcher:
    """Coalesces concurrent requests into model-server batches.

    The model server is driven through ``futures.generate`` so the batcher
    thread goes straight back to coalescing the next group while the mesh
    is still computing the previous one (bounded by ``max_inflight``),
    instead of blocking on one RPC per batch.

    Queued prompts are kept as the transport handed them over — over the
    shm transport that is a zero-copy read-only view aliasing a shared-
    memory slot — and are copied exactly once, into the padded batch
    array. (The slot lease itself stays pinned by each blocked
    ``submit()`` frame until its reply is delivered, so pool residency is
    bounded by in-flight requests — fine for prompt-sized payloads; the
    zero-copy win is on the large generate() replies.) Ragged groups are
    right-padded with token 0; the model sees pad tokens as context
    (generate() has no length mask), so callers wanting exact ragged
    semantics should submit equal-length prompts per group.
    """

    def __init__(self, server, max_batch: int = 8, max_wait_s: float = 0.02,
                 max_inflight: int = 2):
        self._server = server
        self._q: queue.Queue = queue.Queue()
        self._max_batch = max_batch
        self._max_wait = max_wait_s
        self._inflight = threading.Semaphore(max_inflight)
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self.batches = []

    def submit(self, prompt):
        """Blocking request: returns the completed sequence."""
        done = queue.Queue(maxsize=1)
        # asarray, not array: an int32 prompt (incl. a transport-owned
        # view) is queued as-is; the one copy happens in _loop's stack.
        self._q.put((np.asarray(prompt, np.int32), done))
        out = done.get(timeout=120)
        if isinstance(out, BaseException):
            raise out
        return out

    def _loop(self):
        while True:
            first = self._q.get()
            group = [first]
            deadline = time.monotonic() + self._max_wait
            while len(group) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    group.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            # One copy per prompt: transport views -> the padded batch
            # (right-padded with 0 when lengths differ). Rebinding
            # ``group`` to the reply queues drops this thread's prompt
            # references before the batch RPC goes out.
            width = max(len(g[0]) for g in group)
            prompts = np.zeros((len(group), width), np.int32)
            for row, (p, _) in zip(prompts, group):
                row[:len(p)] = p
            group = [done for _, done in group]
            self._inflight.acquire()
            fut = self._server.futures.generate(prompts)
            self.batches.append(len(group))
            fut.add_done_callback(
                lambda f, group=group: self._deliver(group, f))

    def _deliver(self, group, fut):
        self._inflight.release()
        try:
            outs = fut.result()
        except BaseException as exc:  # noqa: BLE001 - fail the waiters
            for done in group:
                done.put(exc)
            return
        for done, row in zip(group, outs):
            done.put(row)

    def stats(self):
        return {"batches": list(self.batches)}


class Client:
    """Closed-loop client with a bounded pipeline window.

    Requests go out as ``futures.submit`` with up to ``window`` in flight
    (rather than one blocking RPC per request), which is what actually
    gives the batcher concurrent prompts to coalesce. Latency samples are
    flushed to the meter in a single ``batch_call`` — N records, one frame.
    """

    def __init__(self, batcher, meter, num_requests: int, prompt_len: int,
                 vocab: int, seed: int, window: int = 4):
        self._batcher = batcher
        self._meter = meter
        self._n = num_requests
        self._rng = np.random.default_rng(seed)
        self._plen = prompt_len
        self._vocab = vocab
        self._window = max(1, window)

    def run(self):
        pending: list[tuple[float, object]] = []
        records: list[tuple[float, int]] = []

        def drain_one():
            t0, fut = pending.pop(0)
            out = fut.result(timeout=120)
            records.append((time.monotonic() - t0, len(out)))

        for _ in range(self._n):
            while len(pending) >= self._window:
                drain_one()
            prompt = self._rng.integers(0, self._vocab, self._plen,
                                        dtype=np.int32)
            pending.append((time.monotonic(),
                            self._batcher.futures.submit(prompt)))
        while pending:
            drain_one()
        self._meter.batch_call(
            [("record", (lat, out_len), {}) for lat, out_len in records])


class Meter:
    def __init__(self, expected: int):
        self._expected = expected
        self._lat = []
        self._lock = threading.Lock()

    def record(self, latency_s: float, out_len: int):
        with self._lock:
            self._lat.append(latency_s)
            done = len(self._lat) >= self._expected
        if done:
            lat = np.array(self._lat)
            print(f"served {len(lat)} requests: "
                  f"p50={np.percentile(lat, 50)*1e3:.1f}ms "
                  f"p95={np.percentile(lat, 95)*1e3:.1f}ms")
            lp.stop_program()


def build_program(model_cfg: ModelConfig, *, num_clients=3,
                  requests_per_client=4, prompt_len=8,
                  max_new=8) -> lp.Program:
    p = lp.Program(f"serve-{model_cfg.name}")
    with p.group("server"):
        server = p.add_node(lp.MeshWorkerNode(ModelServer, model_cfg,
                                              max_new=max_new))
    with p.group("batcher"):
        batcher = p.add_node(lp.CourierNode(Batcher, server))
    meter = p.add_node(lp.CourierNode(
        Meter, num_clients * requests_per_client))
    with p.group("client"):
        for i in range(num_clients):
            p.add_node(lp.CourierNode(
                Client, batcher, meter, requests_per_client, prompt_len,
                model_cfg.vocab_size, seed=i))
    return p


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per client")
    args = ap.parse_args(argv)
    cfg = (configs.get_reduced(args.arch) if args.arch
           else configs.get_reduced("qwen2-1.5b"))
    program = build_program(cfg, num_clients=args.clients,
                            requests_per_client=args.requests)
    print(program)
    lp.launch_and_wait(program, timeout_s=600)


if __name__ == "__main__":
    main()
