"""LM serving as a Launchpad program — continuous batching by default.

Single-engine topology (``--replicas 1 --routers 0``, the PR-4 path):

    frontend clients (CourierNode × N)
      -> batcher (CourierNode: thin admission queue, per-request replies)
      -> model server (MeshWorkerNode: ServeEngine over a slotted KV cache)

Replicated serve fabric (``--replicas N --routers M``, M >= 1):

    frontend clients (CourierNode × N)
      -> routers (CourierNode × M: least-loaded dispatch, failover)
      -> engine servers (MeshWorkerNode × N: one ServeEngine each)
           ⇅ heartbeats (endpoint + load report)
    registry (CourierNode: membership, TTL eviction)

In the fabric, every engine replica registers its endpoint with the
``Registry`` and heartbeats a load report (free KV slots, queue depth,
EWMA us/token); each ``Router`` discovers the live set, dispatches every
request to the least-loaded replica, retries onto a sibling when a
replica dies mid-decode, and fails fast with the typed ``Overloaded``
when every replica is at its admission budget. All of it is plain
Launchpad nodes — thread, process, and test launchers wire it the same
way (see ``repro/serve/router.py``).

Two serving modes share the single-engine topology (``--mode``):

``continuous`` (default)
    The model server runs a :class:`repro.serve.engine.ServeEngine`: a
    persistent decode loop over a fixed pool of KV-cache slots. The
    batcher forwards each request as its own ``futures.generate`` RPC;
    the engine admits it into a free slot between decode steps and the
    reply streams back the moment that one sequence finishes.

``lockstep``
    The PR-3-era baseline, kept for paired A/B: the batcher drains up to
    ``max_batch`` queued prompts, pads them into one batch, and the
    server runs prefill+decode once per batch — every request waits for
    a batch boundary and the whole batch waits for its slowest member.
    Ragged groups are now served *correctly*: the batcher sends the true
    lengths and ``generate`` decodes each row at its own position, so
    pad tokens are never attended as context.

    PYTHONPATH=src python -m repro.launch.serve --requests 12
    PYTHONPATH=src python -m repro.launch.serve --mode lockstep
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 --routers 1
"""

from __future__ import annotations

import argparse
import collections
import json
import queue
import threading
import time

import numpy as np

from repro import configs, core as lp
from repro.core import telemetry
from repro.models.config import ModelConfig
from repro.serve import decode as serve_lib
from repro.serve.router import Router, decorrelated_backoff, is_overloaded

# Bounded, thread-safe history for Batcher.stats(): the worker thread
# appends per-batch sizes while stats() RPCs read concurrently.
STATS_WINDOW = 256


class ModelServer:
    """Lockstep baseline: holds params; serves batched generate() on its mesh.

    ``prompts`` arrives over courier as a read-only array that may alias
    shared transport memory (the shm slot pool) — ``jnp.asarray`` device-
    puts straight from that view, so the Batcher -> ModelServer hop adds
    no extra host copy, and the slot frees when this call returns.
    """

    def __init__(self, model_cfg: ModelConfig, max_new: int = 8, mesh=None):
        import jax
        from repro.models import transformer
        self._cfg = model_cfg
        self._max_new = max_new
        self._params = transformer.init_params(model_cfg, jax.random.key(0))

    def generate(self, prompts, lengths=None):
        import jax.numpy as jnp
        toks = jnp.asarray(np.asarray(prompts, np.int32))
        out = serve_lib.generate(self._cfg, self._params, toks,
                                 max_new=self._max_new,
                                 context_len=toks.shape[1] + self._max_new,
                                 lengths=None if lengths is None
                                 else np.asarray(lengths, np.int32))
        return np.asarray(out)


class EngineServer:
    """Continuous-batching model server: a ServeEngine on this mesh worker.

    ``generate`` blocks its RPC handler thread until that one sequence
    retires — the courier server's handler pool is what lets many
    requests ride the engine concurrently, each reply streaming back
    per-request instead of per-batch.

    With ``registry`` set (the serve fabric), the server registers its
    own endpoint — learned from the worker context, no plumbing through
    the program — and heartbeats its live load report (``load()``:
    free slots, queue depth, EWMA us/token, loaded model version), which
    is the routers' routing signal *and* the rollout controller's version
    table. ``kill()`` crashes the replica in place (stops the engine
    *and* the heartbeats without deregistering): in-flight requests fail
    over, the registry evicts on missed beats — the failure path tests
    and the chaos demo drive exactly this. ``stall``/``drop`` are the
    FaultInjector's softer weapons (missed beats / transport blackhole
    for a window, then recovery).

    With ``store_dir`` set, weights load from a versioned
    :class:`~repro.ckpt.checkpoint.ModelStore` (``version=None`` means
    latest) instead of fresh init, and ``load_version()`` hot-swaps to
    another published version: the restore is checked against the
    current tree (shape identity — same architecture or the RPC fails,
    which is the rollout's health gate firing) and installed between
    decode windows, so the compiled ladder stays warm and in-flight
    requests keep decoding.
    """

    def __init__(self, model_cfg: ModelConfig, max_new: int = 8,
                 num_slots: int = 8, context_len: int | None = None,
                 eos_id: int | None = None, request_timeout_s: float = 120.0,
                 registry=None, heartbeat_s: float = 0.5,
                 name: str | None = None, endpoint: str | None = None,
                 mesh=None, sync_every: int = 8, decode_impl: str = "auto",
                 top_k: int | None = None,
                 prefill_chunk: int | None = None,
                 page_size: int | None = None,
                 num_pages: int | None = None,
                 prefix_cache: bool = True,
                 store_dir: str | None = None,
                 version: int | None = None):
        import jax
        from repro.models import transformer
        from repro.serve.engine import ServeEngine
        self._cfg = model_cfg
        self._timeout = request_timeout_s
        self._store = None
        self._version: int | None = None
        self._drop_until = 0.0
        params = transformer.init_params(model_cfg, jax.random.key(0))
        if store_dir is not None:
            from repro.ckpt.checkpoint import ModelStore
            self._store = ModelStore(store_dir)
            v = self._store.latest_version() if version is None else version
            if v is None:
                raise ValueError(f"model store {store_dir!r} has no "
                                 "published versions")
            params = self._store.load_version(int(v), like=params)
            self._version = int(v)
        self._engine = ServeEngine(
            model_cfg, params, num_slots=num_slots,
            context_len=context_len or 128,
            max_new=max_new, eos_id=eos_id, sync_every=sync_every,
            decode_impl=decode_impl, top_k=top_k,
            prefill_chunk=prefill_chunk, page_size=page_size,
            num_pages=num_pages, prefix_cache=prefix_cache)
        self._engine.start()
        self._heartbeater = None
        if registry is not None:
            ctx = lp.get_current_context()
            name = name or ctx.node_name
            endpoint = endpoint or ctx.endpoint
            if endpoint is None:
                raise ValueError(
                    "EngineServer(registry=...) needs a serving endpoint: "
                    "run it as a courier-serving node or pass endpoint=")
            self._heartbeater = lp.Heartbeater(
                registry, name, endpoint, load_fn=self.load,
                period_s=heartbeat_s, stop_event=ctx.stop_event).start()

    def generate(self, prompt, max_new=None):
        if time.monotonic() < self._drop_until:
            raise ConnectionError("transport drop (fault injection)")
        fut = self._engine.submit(np.asarray(prompt, np.int32).reshape(-1),
                                  max_new=max_new)
        from concurrent import futures as cf
        try:
            return fut.result(timeout=self._timeout)
        except cf.TimeoutError:
            # A queued (not yet admitted) request is cancellable: don't
            # let an abandoned reply go on to occupy a slot.
            fut.cancel()
            raise

    def load(self):
        """The routing signal: free slots, queued requests, EWMA us/token —
        plus the loaded model version, which the heartbeat carries into
        the Registry's version table (the rollout's source of truth)."""
        report = self._engine.load()
        if self._version is not None:
            report["version"] = self._version
        return report

    def health(self):
        status = "ok" if self._engine.alive else "stopped"
        return {"status": status, **self.load()}

    def load_version(self, version):
        """Hot-swap to a published model version (the rollout's swap
        step). Restores against the current tree — a version published
        for a different architecture fails *here*, before any weight is
        installed — then applies between decode windows."""
        if self._store is None:
            raise RuntimeError("EngineServer has no model store attached "
                               "(pass store_dir=)")
        params = self._store.load_version(int(version),
                                          like=self._engine._params)
        self._engine.swap_params(params)
        self._version = int(version)
        if self._heartbeater is not None:
            # Don't wait a beat period to advertise the new version.
            self._heartbeater.beat_now()
        return {"version": self._version}

    def stall(self, seconds: float):
        """Fault hook: miss heartbeats for ``seconds`` — the registry
        TTL-evicts this replica, then its resumed beats re-register it
        (the stall → evict → revive cycle). The engine keeps serving
        whatever is already in flight."""
        telemetry.record_event("stall", cause=f"heartbeats paused "
                               f"{seconds}s (fault injection)")
        if self._heartbeater is not None:
            self._heartbeater.pause(seconds)
        return "stalled"

    def drop(self, seconds: float):
        """Fault hook: blackhole the request transport for ``seconds`` —
        ``generate`` raises ``ConnectionError``, routers fail over and
        report the failure; heartbeats continue, so the replica
        re-registers and recovers once the window passes."""
        telemetry.record_event("drop", cause=f"transport blackholed "
                               f"{seconds}s (fault injection)")
        self._drop_until = time.monotonic() + float(seconds)
        return "dropped"

    def kill(self):
        """Simulate a replica crash: stop heartbeats (no deregistration)
        and the engine, failing everything in flight. The fabric's job is
        to make this invisible to clients."""
        telemetry.record_event("kill", cause="replica killed "
                               "(fault injection)")
        if self._heartbeater is not None:
            self._heartbeater.stop(deregister=False)
        self._engine.stop()
        return "killed"

    def stats(self):
        return self._engine.stats()

    def telemetry(self):
        """Telemetry scrape target: process metrics + drained span/event
        rings, with the engine's full counter set as the service payload
        (the hub files it per node name)."""
        return telemetry.telemetry_snapshot(service=self._engine.stats())


class Batcher:
    """Admission front for the model server.

    ``mode="continuous"``: thin pass-through — each ``submit`` forwards
    the prompt as its own ``futures.generate`` RPC and blocks its handler
    thread for that one reply; all batching happens inside the engine at
    decode-step granularity.

    ``mode="lockstep"``: the classic coalescing worker (the A/B
    baseline). The model server is driven through ``futures.generate`` so
    the batcher thread goes straight back to coalescing the next group
    while the mesh is still computing the previous one (bounded by
    ``max_inflight``). Queued prompts are kept as the transport handed
    them over (zero-copy views) and copied exactly once into the padded
    batch; the true lengths ride along so ragged groups decode at their
    own positions instead of attending to pad tokens.
    """

    def __init__(self, server, max_batch: int = 8, max_wait_s: float = 0.02,
                 max_inflight: int = 2, mode: str = "continuous",
                 request_timeout_s: float = 150.0):
        if mode not in ("continuous", "lockstep"):
            raise ValueError(f"unknown serve mode {mode!r}")
        self._server = server
        self._mode = mode
        # Above the engine server's own per-request timeout, so a server-
        # side timeout surfaces as the reply instead of racing this one.
        self._timeout = request_timeout_s
        self._q: queue.Queue = queue.Queue()
        self._max_batch = max_batch
        self._max_wait = max_wait_s
        self._inflight = threading.Semaphore(max_inflight)
        self._stats_lock = threading.Lock()
        self._batches = collections.deque(maxlen=STATS_WINDOW)
        self._submitted = 0
        if mode == "lockstep":
            self._worker = threading.Thread(target=self._loop, daemon=True)
            self._worker.start()

    def submit(self, prompt):
        """Blocking request: returns the completed sequence."""
        with self._stats_lock:
            self._submitted += 1
        if self._mode == "continuous":
            # Thin admission: one request, one RPC, one streamed reply.
            fut = self._server.futures.generate(
                np.asarray(prompt, np.int32))
            return fut.result(timeout=self._timeout)
        done = queue.Queue(maxsize=1)
        # asarray, not array: an int32 prompt (incl. a transport-owned
        # view) is queued as-is; the one copy happens in _loop's stack.
        self._q.put((np.asarray(prompt, np.int32), done))
        out = done.get(timeout=self._timeout)
        if isinstance(out, BaseException):
            raise out
        return out

    def _loop(self):
        while True:
            first = self._q.get()
            group = [first]
            deadline = time.monotonic() + self._max_wait
            while len(group) < self._max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    group.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            # One copy per prompt: transport views -> the padded batch
            # (right-padded with 0 when lengths differ). Rebinding
            # ``group`` to the reply queues drops this thread's prompt
            # references before the batch RPC goes out.
            lengths = np.array([len(g[0]) for g in group], np.int32)
            prompts = np.zeros((len(group), int(lengths.max())), np.int32)
            for row, (p, _) in zip(prompts, group):
                row[:len(p)] = p
            group = [done for _, done in group]
            self._inflight.acquire()
            fut = self._server.futures.generate(prompts, lengths)
            with self._stats_lock:
                self._batches.append(len(group))
            fut.add_done_callback(
                lambda f, group=group: self._deliver(group, f))

    def _deliver(self, group, fut):
        self._inflight.release()
        try:
            outs = fut.result()
        except BaseException as exc:  # noqa: BLE001 - fail the waiters
            for done in group:
                done.put(exc)
            return
        for done, row in zip(group, outs):
            done.put(row)

    def stats(self):
        with self._stats_lock:
            return {"mode": self._mode,
                    "submitted": self._submitted,
                    "batches": list(self._batches)}


class Client:
    """Closed-loop client with a bounded pipeline window.

    Requests go out as ``futures.submit`` with up to ``window`` in flight
    (rather than one blocking RPC per request), which is what actually
    gives the serving side concurrent prompts. Latency samples are
    flushed to the meter in a single ``batch_call`` — N records, one frame.
    """

    def __init__(self, batcher, meter, num_requests: int, prompt_len: int,
                 vocab: int, seed: int, window: int = 4, source: str = "",
                 trace_every: int = 0):
        self._batcher = batcher
        self._meter = meter
        self._n = num_requests
        self._rng = np.random.default_rng(seed)
        self._plen = prompt_len
        self._vocab = vocab
        self._window = max(1, window)
        # Which admission front this client talks to (router/batcher node
        # label) — the meter namespaces its percentiles by it.
        self._source = source
        # Trace sampling: every Nth request carries a trace envelope (0 =
        # off). The sampled request's root "request" span is the measured
        # e2e window every downstream span must account for.
        self._trace_every = max(0, int(trace_every))

    def _submit(self, prompt, trace):
        if trace is None:
            return self._batcher.futures.submit(prompt)
        # Current-thread context drives injection at the courier proxy;
        # the envelope's parent is the pre-minted root span id, so every
        # remote span nests under the "request" root.
        with telemetry.activate(trace[0].child(trace[1])):
            return self._batcher.futures.submit(prompt)

    def run(self):
        pending: list[tuple] = []
        records: list[tuple[float, int]] = []

        def drain_one():
            t0, prompt, fut, trace = pending.pop(0)
            backoff = 0.0
            while True:
                try:
                    out = fut.result(timeout=120)
                    break
                except BaseException as exc:  # noqa: BLE001
                    # Overloaded is the fabric's retry-later signal;
                    # latency keeps accruing from the first attempt.
                    # Decorrelated jitter on the resubmit: every client
                    # sees Overloaded at the same moment when capacity
                    # dips (a drain, a kill) — a fixed schedule would
                    # have them all stampede back on the same tick.
                    if not is_overloaded(exc):
                        raise
                    backoff = decorrelated_backoff(backoff, self._rng,
                                                   base_s=0.005, cap_s=0.2)
                    time.sleep(backoff)
                    fut = self._submit(prompt, trace)
            if trace is not None:
                ctx, root_sid, t0w, t0p = trace
                telemetry.record_span("request", ctx, t0w,
                                      time.perf_counter() - t0p,
                                      span_id=root_sid, root=True,
                                      out_len=len(out))
            records.append((time.monotonic() - t0, len(out)))

        for k in range(self._n):
            while len(pending) >= self._window:
                drain_one()
            prompt = self._rng.integers(0, self._vocab, self._plen,
                                        dtype=np.int32)
            trace = None
            if self._trace_every and k % self._trace_every == 0:
                trace = (telemetry.start_trace(), telemetry.new_span_id(),
                         time.time(), time.perf_counter())
            pending.append((time.monotonic(), prompt,
                            self._submit(prompt, trace), trace))
        while pending:
            drain_one()
        self._meter.batch_call(
            [("record", (lat, out_len), {"source": self._source})
             for lat, out_len in records])


class Meter:
    """Collects request latencies; prints percentiles and (optionally)
    writes the summary to a JSON file before stopping the program.

    Built on the telemetry histogram layer: every record lands in a
    per-source :class:`repro.core.telemetry.Histogram` registered as
    ``meter.latency_ms.<source>`` in the process metrics registry — so
    the same numbers the meter prints are scrapable through any
    ``telemetry()`` RPC, and the summary's count/mean are exact while
    p50/p95 are log2-bucket approximations (<= ~4.5% relative error, the
    histogram's bucket width). The summary JSON keeps its shape: the
    top-level keys are the merged roll-up row (histograms merge by
    bucket) with per-source summaries namespaced under ``per_source``.

    ``holds`` delays the program stop past the last served request: each
    hold is dropped by a ``release()`` RPC, and the stop fires only once
    the count is reached AND every hold is released. The rollout demo
    uses one hold so a RolloutDriver that gets scheduled late (starved
    thread on a loaded host) still finds the fleet's courier services
    registered instead of racing program teardown.
    """

    def __init__(self, expected: int, summary_path: str | None = None,
                 holds: int = 0):
        self._expected = expected
        self._summary_path = summary_path
        self._hists: dict[str, telemetry.Histogram] = {}
        self._count = 0
        self._holds = holds
        self._summary_done = False
        self._lock = threading.Lock()

    @staticmethod
    def _percentiles(h: telemetry.Histogram) -> dict:
        return {"count": int(h.count),
                "p50_ms": float(h.percentile(50)),
                "p95_ms": float(h.percentile(95)),
                "mean_ms": float(h.mean)}

    def record(self, latency_s: float, out_len: int, source: str = ""):
        with self._lock:
            src = source or "default"
            h = self._hists.get(src)
            if h is None:
                h = telemetry.metrics().histogram(f"meter.latency_ms.{src}")
                # This meter's lifetime scopes the window: the registry
                # entry may survive from a previous program in the same
                # process (thread launcher, tests) and must not leak its
                # counts into this run's summary.
                h.reset()
                self._hists[src] = h
            h.record(latency_s * 1e3)       # stored in ms: keys read direct
            self._count += 1
            done = self._count >= self._expected and not self._summary_done
            if done:
                self._summary_done = True
            stop = self._count >= self._expected and self._holds == 0
        if done:
            merged = telemetry.Histogram("meter.latency_ms")
            for h in self._hists.values():
                merged.merge(h)
            summary = self._percentiles(merged)   # the merged roll-up row
            if len(self._hists) > 1 or "default" not in self._hists:
                summary["per_source"] = {
                    src: self._percentiles(h)
                    for src, h in sorted(self._hists.items())}
            print(f"served {summary['count']} requests: "
                  f"p50={summary['p50_ms']:.1f}ms "
                  f"p95={summary['p95_ms']:.1f}ms")
            if self._summary_path:
                with open(self._summary_path, "w") as f:
                    json.dump(summary, f, indent=2)
                    f.write("\n")
        if stop:
            lp.stop_program()

    def telemetry(self):
        """Scrape target (explicit hub handle in the fabric program)."""
        return telemetry.telemetry_snapshot()

    def release(self, tag: str = "") -> None:
        """Drop one stop-hold (e.g. the RolloutDriver finished its roll)."""
        with self._lock:
            self._holds = max(0, self._holds - 1)
            stop = self._count >= self._expected and self._holds == 0
        if stop:
            lp.stop_program()


def build_program(model_cfg: ModelConfig, *, num_clients=3,
                  requests_per_client=4, prompt_len=8, max_new=8,
                  mode: str = "continuous", num_slots: int = 8,
                  meter_json: str | None = None, replicas: int = 1,
                  routers: int = 0, registry_ttl_s: float = 2.0,
                  heartbeat_s: float = 0.25,
                  kill_after: int | None = None,
                  page_size: int | None = None,
                  num_pages: int | None = None,
                  store_dir: str | None = None,
                  model_version: int | None = None,
                  rollout: int | None = None,
                  rollout_after: int | None = None,
                  canary_fraction: float = 0.25,
                  telemetry_dir: str | None = None,
                  trace_every: int = 0) -> lp.Program:
    """Wire the serving topology as a Launchpad program.

    ``routers == 0`` (default) is the direct PR-4 path — one engine (or
    the lockstep baseline) behind a Batcher; ``replicas`` must be 1.
    ``routers >= 1`` builds the replicated serve fabric:
    Registry -> Routers -> EngineServers, clients partitioned across
    routers round-robin. ``kill_after`` adds a FaultInjector node that
    kills replica 0 once that many requests have been served — mid-run
    by construction (the failover demo: traffic must keep flowing).

    ``store_dir`` points the engines at a versioned ModelStore
    (``model_version`` picks the starting version; None = latest), and
    ``rollout=V`` adds a RolloutController node that rolls the fleet to
    version ``V`` once ``rollout_after`` requests have been served —
    drain, hot-swap, canary-compare, promote (or roll back), while the
    clients' traffic keeps completing.

    ``telemetry_dir`` adds a TelemetryHub node (fabric topology only)
    that scrapes every replica through the registry — plus the routers
    and meter by handle — and writes ``telemetry.json`` +
    ``trace.json`` (Perfetto) there. ``trace_every=N`` makes every
    client trace its every Nth request end to end.
    """
    p = lp.Program(f"serve-{model_cfg.name}")
    total = num_clients * requests_per_client

    if routers < 1:
        if replicas != 1:
            raise ValueError("replicas > 1 needs at least one router "
                             "(--routers 1)")
        if kill_after is not None:
            raise ValueError("the failover demo needs the fabric "
                             "(--routers >= 1 and --replicas >= 2)")
        with p.group("server"):
            if mode == "continuous":
                server = p.add_node(lp.MeshWorkerNode(
                    EngineServer, model_cfg, max_new=max_new,
                    num_slots=num_slots, context_len=prompt_len + max_new,
                    page_size=page_size, num_pages=num_pages))
            else:
                server = p.add_node(lp.MeshWorkerNode(ModelServer, model_cfg,
                                                      max_new=max_new))
        with p.group("batcher"):
            batcher = p.add_node(lp.CourierNode(Batcher, server, mode=mode))
        meter = p.add_node(lp.CourierNode(Meter, total,
                                          summary_path=meter_json))
        with p.group("client"):
            for i in range(num_clients):
                p.add_node(lp.CourierNode(
                    Client, batcher, meter, requests_per_client, prompt_len,
                    model_cfg.vocab_size, seed=i, trace_every=trace_every))
        return p

    if mode != "continuous":
        raise ValueError("the serve fabric routes to continuous-batching "
                         "engines only (drop --mode lockstep)")
    if kill_after is not None and replicas < 2:
        raise ValueError("killing a replica with no sibling loses requests "
                         "by construction; use --replicas >= 2")
    if kill_after is not None and kill_after >= total:
        raise ValueError(f"--kill-after {kill_after} never fires: only "
                         f"{total} requests will be served")
    if rollout is not None:
        if store_dir is None:
            raise ValueError("rollout= needs store_dir= (a ModelStore with "
                             "the target version published)")
        if rollout_after is None or rollout_after >= total:
            raise ValueError("rollout= needs rollout_after < total requests "
                             "so the roll happens under load")

    with p.group("registry"):
        registry = p.add_node(lp.CourierNode(lp.Registry,
                                             ttl_s=registry_ttl_s))
    replica_handles = []
    with p.group("server"):
        for _ in range(replicas):
            replica_handles.append(p.add_node(lp.MeshWorkerNode(
                EngineServer, model_cfg, max_new=max_new,
                num_slots=num_slots, context_len=prompt_len + max_new,
                page_size=page_size, num_pages=num_pages,
                registry=registry, heartbeat_s=heartbeat_s,
                store_dir=store_dir, version=model_version)))
    router_nodes, router_handles = [], []
    with p.group("router"):
        for _ in range(routers):
            node = lp.CourierNode(Router, registry,
                                  refresh_s=heartbeat_s)
            router_handles.append(p.add_node(node))
            router_nodes.append(node)
    meter = p.add_node(lp.CourierNode(Meter, total, summary_path=meter_json,
                                      holds=1 if rollout is not None else 0))
    with p.group("client"):
        for i in range(num_clients):
            m = i % routers
            p.add_node(lp.CourierNode(
                Client, router_handles[m], meter, requests_per_client,
                prompt_len, model_cfg.vocab_size, seed=i,
                source=router_nodes[m].name, trace_every=trace_every))
    if telemetry_dir is not None:
        with p.group("telemetry"):
            p.add_node(lp.PyNode(
                lp.TelemetryHub, registry,
                targets=list(router_handles) + [meter, registry],
                poll_s=max(heartbeat_s, 0.1), out_dir=telemetry_dir))
    if kill_after is not None:
        with p.group("chaos"):
            p.add_node(lp.PyNode(
                lp.FaultInjector,
                [lp.FaultEvent(kind="kill", target=0,
                               after_served=kill_after)],
                [replica_handles[0]], progress=list(router_handles)))
    if rollout is not None:
        with p.group("rollout"):
            p.add_node(lp.PyNode(RolloutDriver, registry,
                                 list(router_handles), rollout,
                                 rollout_after,
                                 canary_fraction=canary_fraction,
                                 meter=meter))
    return p


class RolloutDriver:
    """Program node that triggers a fleet rollout mid-run: once the
    routers have completed ``after_served`` requests (count-based, like
    the FaultInjector's kill trigger — lands mid-run on any host speed),
    it runs a :class:`~repro.serve.rollout.RolloutController` against the
    registry. All rollout state lives in the registry's version table, so
    this node restarting just re-runs ``rollout()`` and resumes.

    The driver pins the program open: it holds one Meter stop-hold (see
    ``Meter.release``) until its roll completes, so the fleet's courier
    services are guaranteed to still be registered when it runs — even
    when this thread is scheduled so late (loaded host) that every
    request has already been served."""

    def __init__(self, registry, routers, version: int, after_served: int,
                 canary_fraction: float = 0.25, canary_requests: int = 4,
                 canary_timeout_s: float = 5.0, meter=None):
        self._registry = registry
        self._routers = routers
        self._version = version
        self._after = after_served
        self._canary_fraction = canary_fraction
        self._canary_requests = canary_requests
        self._canary_timeout = canary_timeout_s
        self._meter = meter

    def run(self):
        from repro.serve.rollout import RolloutController
        ctx = lp.get_current_context()
        try:
            while not ctx.wait_for_stop(0.002):
                try:
                    done = sum(r.stats()["completed"]
                               for r in self._routers)
                except Exception:
                    # Bring-up race: routers register their courier
                    # services asynchronously, and on a loaded host that
                    # can outlast one lookup window. Transient here —
                    # keep polling instead of taking the program down
                    # (launch_and_wait runs fail-fast, max_restarts=0).
                    continue
                if done < self._after:
                    continue
                result = RolloutController(
                    self._registry, self._routers,
                    canary_fraction=self._canary_fraction,
                    canary_requests=self._canary_requests,
                    canary_timeout_s=self._canary_timeout,
                ).rollout(self._version)
                print(f"rollout: {result['status']} -> v{self._version} "
                      f"in {result.get('duration_s', 0.0):.2f}s", flush=True)
                return
        finally:
            if self._meter is not None:
                try:
                    self._meter.release("rollout")
                except Exception:
                    pass


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per client")
    ap.add_argument("--mode", choices=("continuous", "lockstep"),
                    default="continuous")
    ap.add_argument("--slots", type=int, default=8,
                    help="KV-cache slots (continuous mode)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged KV mode: tokens per page (None = flat)")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged KV mode: pool size in pages "
                         "(default slots * ceil(context/page_size))")
    ap.add_argument("--meter-json", default=None,
                    help="write the latency percentile summary here")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas (>1 needs --routers >= 1)")
    ap.add_argument("--routers", type=int, default=0,
                    help="fabric routers; 0 = direct single-engine path")
    ap.add_argument("--kill-after", type=int, default=None, metavar="N",
                    help="failover demo: kill replica 0 after N requests "
                         "have been served (deterministically mid-run)")
    ap.add_argument("--store", default=None,
                    help="ModelStore directory (created and seeded with "
                         "v0/v1 for the rollout demo when absent)")
    ap.add_argument("--rollout-after", type=int, default=None, metavar="N",
                    help="rollout demo: roll the fleet v0 -> v1 after N "
                         "requests (needs the fabric; publishes both "
                         "versions into --store first)")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="fabric only: run a TelemetryHub and write "
                         "telemetry.json + trace.json (Perfetto) here")
    ap.add_argument("--trace-every", type=int, default=0, metavar="N",
                    help="trace every Nth request per client end to end "
                         "(0 = tracing off)")
    args = ap.parse_args(argv)
    cfg = (configs.get_reduced(args.arch) if args.arch
           else configs.get_reduced("qwen2-1.5b"))
    store_dir, model_version, rollout = args.store, None, None
    if args.rollout_after is not None:
        import tempfile
        import jax
        from repro.ckpt.checkpoint import ModelStore, config_hash
        from repro.models import transformer
        store_dir = store_dir or tempfile.mkdtemp(prefix="modelstore-")
        store = ModelStore(store_dir)
        for v in (0, 1):
            if v not in store.versions():
                store.publish_version(
                    v, transformer.init_params(cfg, jax.random.key(v)),
                    metadata={"step": v, "config_hash": config_hash(cfg)})
        model_version, rollout = 0, 1
    program = build_program(cfg, num_clients=args.clients,
                            requests_per_client=args.requests,
                            mode=args.mode, num_slots=args.slots,
                            meter_json=args.meter_json,
                            replicas=args.replicas, routers=args.routers,
                            kill_after=args.kill_after,
                            page_size=args.page_size, num_pages=args.pages,
                            store_dir=store_dir, model_version=model_version,
                            rollout=rollout,
                            rollout_after=args.rollout_after,
                            telemetry_dir=args.telemetry_dir,
                            trace_every=args.trace_every)
    print(program)
    lp.launch_and_wait(program, timeout_s=600)


if __name__ == "__main__":
    main()
