"""Dry-run cell construction: per-(arch × shape) step functions, abstract
inputs (ShapeDtypeStruct — no allocation), shardings, and the napkin-math
cell plan (microbatching / remat / residual sharding) that makes each cell
fit a 16 GiB v5e chip.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import transformer
from repro.models.config import (ALL_SHAPES, ModelConfig, ShapeConfig,
                                 shape_applicability)
from repro.serve import decode as serve_lib
from repro.sharding import ShardingCtx, use_sharding
from repro.sharding.rules import batch_spec, fit_spec, param_sharding
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainConfig, make_train_state,
                                    make_train_step, train_state_shapes)

SHAPES = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Cell plan: napkin math -> microbatching / remat / residual sharding
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CellPlan:
    num_microbatches: int = 1
    remat: str = "full"
    grad_accum_dtype: str = "float32"
    resid_tp: bool = False        # shard saved residuals over TP (FSDP+SP)
    unroll_micro: bool = False    # probes only: unrolled microbatch loop
    notes: str = ""


def _train_mem_estimate(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        nm: int, resid_tp: bool) -> float:
    """Per-device live activation bytes at microbatch size b_local/nm."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tp = mesh.shape.get("model", 1)
    bm = max(shape.global_batch // dp // nm, 1)
    S = shape.seq_len
    # remat=full saves superblock inputs [bm, S, D] bf16 per layer.
    width_factor = 2.0 if cfg.family == "ssm" else 1.0
    resid = bm * S * cfg.d_model * 2 * cfg.num_layers * width_factor
    if resid_tp:
        resid /= tp
    # Live attention logits (f32 + softmax copy), padded heads over TP.
    attn = 0.0
    if cfg.num_heads:
        hp = cfg.num_heads + ((-cfg.num_heads) % tp)
        span = min(S, cfg.window or S)
        attn = bm * (hp / tp) * min(S, 2048 * 2) * span * 4 * 2
    return resid + attn


def plan_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> CellPlan:
    if shape.kind != "train":
        return CellPlan(notes="forward-only: no activation accumulation")
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_local = max(shape.global_batch // dp, 1)
    budget = 2.5e9
    nm, resid_tp = 1, False
    while nm < b_local and _train_mem_estimate(cfg, shape, mesh, nm,
                                               resid_tp) > budget:
        nm *= 2
    if _train_mem_estimate(cfg, shape, mesh, nm, resid_tp) > budget:
        resid_tp = True   # microbatch of 1 still too big: SP the residuals
    est = _train_mem_estimate(cfg, shape, mesh, nm, resid_tp)
    accum = "bfloat16" if cfg.param_count() > 5e10 else "float32"
    return CellPlan(num_microbatches=nm, remat="full",
                    grad_accum_dtype=accum, resid_tp=resid_tp,
                    notes=f"b_local={b_local} est_act={est/1e9:.2f}GB")


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def _dp(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def batch_shardings(mesh: Mesh, batch_tree):
    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        spec = batch_spec(mesh, x.ndim)
        # Divisibility fit: long_500k has global_batch=1 — stays replicated.
        return NamedSharding(mesh, fit_spec(mesh, x.shape, tuple(spec)))
    return jax.tree.map(leaf, batch_tree)


def state_shardings(mesh: Mesh, state_tree):
    """Decode-state sharding: batch over DP; KV heads (or failing that the
    cache length), recurrent widths over TP."""
    dp = _dp(mesh)

    def leaf(path, x):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        stacked = any(getattr(k, "key", None) == "blocks" for k in path)
        core = x.shape[1:] if stacked else x.shape
        if name in ("k", "v", "k_mem", "v_mem"):     # [B, L, KV, dh]
            spec = [dp, None, "model", None]
            if core[2] % mesh.shape["model"]:
                spec = [dp, "model", None, None]     # shard cache length
        elif name == "h" and len(core) == 3:          # mamba [B, Di, N]
            spec = [dp, "model", None]
        elif name == "h":                             # rg-lru [B, W]
            spec = [dp, "model"]
        elif name == "conv":                          # [B, K-1, W/Di]
            spec = [dp, None, "model"]
        else:
            spec = [None] * len(core)
        if stacked:
            spec = [None] + spec
        return NamedSharding(mesh, fit_spec(mesh, x.shape, spec))

    return jax.tree_util.tree_map_with_path(leaf, state_tree)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract model-input batch for one step (the paper-shape cell)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32)}
    batch: dict[str, Any] = {}
    if cfg.family == "audio":
        batch["embeddings"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            batch["targets"] = _sds((B, S), jnp.int32)
            batch["mask"] = _sds((B, S), jnp.float32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), jnp.int32)
        if cfg.family == "vlm":
            batch["image_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                                         jnp.bfloat16)
    return batch


def input_specs(arch: str, shape_name: str) -> dict:
    """Public helper (brief requirement): ShapeDtypeStruct stand-ins for
    every model input of the given cell."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    specs = {"batch": batch_specs(cfg, shape)}
    if shape.kind == "train":
        params, opt = train_state_shapes(cfg)
        specs["params"], specs["opt_state"] = params, opt
    else:
        specs["params"] = serve_param_shapes(cfg)
        if shape.kind == "decode":
            specs["state"] = transformer.decode_state_spec(
                cfg, shape.global_batch, shape.seq_len)
    return specs


def serve_param_shapes(cfg: ModelConfig):
    """Inference params are bf16."""
    shapes = transformer.param_shapes(cfg)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        shapes)


# ---------------------------------------------------------------------------
# Step builders: (fn, abstract_args, in_shardings, out_shardings, donate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellStep:
    fn: Any
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    plan: CellPlan
    model_flops_per_device: float


def _model_flops(cfg: ModelConfig, shape: ShapeConfig, n_dev: int) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_dev
    return 2.0 * n_active * shape.global_batch / n_dev  # decode: 1 tok/seq


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               plan: Optional[CellPlan] = None) -> CellStep:
    plan = plan or plan_cell(cfg, shape, mesh)
    n_dev = mesh.size
    mflops = _model_flops(cfg, shape, n_dev)
    batch = batch_specs(cfg, shape)
    batch_sh = batch_shardings(mesh, batch)

    if shape.kind == "train":
        tc = TrainConfig(
            optimizer=OptimizerConfig(),
            num_microbatches=plan.num_microbatches,
            remat=plan.remat,
            grad_accum_dtype=plan.grad_accum_dtype,
            resid_tp=plan.resid_tp,
            unroll_micro=plan.unroll_micro)
        step = make_train_step(cfg, tc)
        params, opt = train_state_shapes(cfg)
        p_sh = param_sharding(params, mesh)
        o_sh = param_sharding(opt, mesh)
        return CellStep(
            fn=step, args=(params, opt, batch),
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1), plan=plan,
            model_flops_per_device=mflops)

    params = serve_param_shapes(cfg)
    p_sh = param_sharding(params, mesh)

    if shape.kind == "prefill":
        if cfg.decode_supported:
            fn = serve_lib.make_prefill(cfg, context_len=shape.seq_len)
            def prefill_fn(params, batch):
                logits, state = fn(params, batch.get("tokens"),
                                   memory=batch.get("image_embeds"),
                                   embeddings=batch.get("embeddings"))
                return logits.astype(jnp.bfloat16), state
            state = transformer.decode_state_spec(cfg, shape.global_batch,
                                                  shape.seq_len)
            out_sh = (None, state_shardings(mesh, state))
        else:
            def prefill_fn(params, batch):
                hidden, _ = transformer.forward(
                    cfg, params, tokens=batch.get("tokens"),
                    embeddings=batch.get("embeddings"),
                    memory=batch.get("image_embeds"))
                logits = transformer.logits_from_hidden(cfg, params, hidden)
                return logits.astype(jnp.bfloat16)
            out_sh = None
        return CellStep(
            fn=prefill_fn, args=(params, batch),
            in_shardings=(p_sh, batch_sh),
            out_shardings=out_sh,
            donate_argnums=(), plan=plan,
            model_flops_per_device=mflops)

    # decode
    state = transformer.decode_state_spec(cfg, shape.global_batch,
                                          shape.seq_len)
    s_sh = state_shardings(mesh, state)
    serve_step = serve_lib.make_serve_step(cfg)
    t = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, state, tokens, t):
        return serve_step(params, state, tokens, t)

    return CellStep(
        fn=decode_fn,
        args=(params, state, batch["tokens"], t),
        in_shardings=(p_sh, s_sh, batch_sh["tokens"], NamedSharding(mesh, P())),
        out_shardings=(None, s_sh),
        donate_argnums=(1,), plan=plan,
        model_flops_per_device=mflops)


def lower_cell(cell: CellStep, mesh: Mesh):
    """Trace+lower under the activation-sharding context for ``mesh``."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ctx = ShardingCtx(mesh, dp=dp, tp=("model",))
    jitted = jax.jit(cell.fn,
                     in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    with use_sharding(ctx):
        return jitted.lower(*cell.args)
