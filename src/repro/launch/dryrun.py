import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before jax (or anything importing jax)
# initializes: jax locks the device count on first init, and the dry-run
# needs 512 placeholder host devices to build the production meshes.

"""Multi-pod dry-run driver.

For every (architecture × input shape) cell:

  * single-pod mesh (16×16):  full-config ``lower().compile()`` — the
    memory/sharding proof (memory_analysis recorded) — plus two unrolled
    depth probes (1 and 2 superblocks) for exact per-layer HLO FLOPs /
    bytes / collective bytes (see repro.roofline.analysis).
  * multi-pod mesh (2×16×16): full-config ``lower().compile()`` — proves
    the 'pod' axis shards (DP over DCN).

Results land in ``artifacts/dryrun/<mesh>/<arch>__<shape>.json``; cells
that are structurally inapplicable record their skip reason.

Usage:
    python -m repro.launch.dryrun                      # everything
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --mesh multi --skip-existing
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import cells as cells_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models import scan_utils
from repro.models.config import ALL_SHAPES, shape_applicability
from repro.roofline import analysis, hw

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _probe_cfg(cfg, n_superblocks: int):
    return dataclasses.replace(cfg, num_layers=n_superblocks * len(cfg.pattern))


def _compile_cell(cfg, shape, mesh, plan=None):
    cell = cells_lib.build_cell(cfg, shape, mesh, plan=plan)
    t0 = time.time()
    lowered = cells_lib.lower_cell(cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return cell, compiled, t1 - t0, t2 - t1


def _probe_costs(cfg, shape, mesh, plan):
    """Unrolled 1- and 2-superblock compiles -> extrapolated per-step cost."""
    from repro.models import attention
    # Probes keep the production microbatch count but UNROLL the micro loop
    # (scan bodies are cost-counted once), so per-microbatch weight
    # all-gathers / grad reduce-scatters are visible. The per-device batch
    # is identical either way; FLOPs/bytes totals match production.
    probe_plan = dataclasses.replace(plan, unroll_micro=True)
    saved_thresh = transformer.SCAN_UNROLL_THRESHOLD
    saved_chunk = scan_utils.FORCE_SINGLE_CHUNK
    saved_mode = attention.CHUNK_MODE
    transformer.SCAN_UNROLL_THRESHOLD = 4
    scan_utils.FORCE_SINGLE_CHUNK = True
    attention.CHUNK_MODE = "unrolled"
    try:
        costs = []
        for n_sb in (1, 2):
            pcfg = _probe_cfg(cfg, n_sb)
            _, compiled, _, _ = _compile_cell(pcfg, shape, mesh, probe_plan)
            costs.append(analysis.cost_from_compiled(compiled, mesh.size))
        micro_scale = plan.num_microbatches if shape.kind == "train" else 1.0
        # probes run one microbatch of the full global batch; production
        # runs num_micro microbatches of 1/num_micro the size -> identical
        # totals, so micro_scale stays 1 for flops/bytes. (Kept explicit.)
        total = analysis.extrapolate(costs[0], costs[1],
                                     cfg.num_layers / len(cfg.pattern),
                                     micro_scale=1.0)
        return total, costs
    finally:
        transformer.SCAN_UNROLL_THRESHOLD = saved_thresh
        scan_utils.FORCE_SINGLE_CHUNK = saved_chunk
        attention.CHUNK_MODE = saved_mode


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             with_probes: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = cells_lib.SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "mesh_shape": list(tuple(mesh.shape.values())),
                    "devices": mesh.size}

    skip = shape_applicability(cfg, shape)
    if skip:
        record.update(status="skipped", reason=skip)
        return record

    try:
        plan = cells_lib.plan_cell(cfg, shape, mesh)
        cell, compiled, lower_s, compile_s = _compile_cell(cfg, shape, mesh, plan)
        ma = compiled.memory_analysis()
        record.update(
            status="ok",
            plan=dataclasses.asdict(plan),
            lower_s=round(lower_s, 2), compile_s=round(compile_s, 2),
            memory={
                "argument_gb": ma.argument_size_in_bytes / 1e9,
                "output_gb": ma.output_size_in_bytes / 1e9,
                "temp_gb": ma.temp_size_in_bytes / 1e9,
                "alias_gb": ma.alias_size_in_bytes / 1e9,
                "peak_estimate_gb": (ma.argument_size_in_bytes
                                     + ma.output_size_in_bytes
                                     + ma.temp_size_in_bytes
                                     - ma.alias_size_in_bytes) / 1e9,
                "hbm_gb": hw.HBM_BYTES / 1e9,
            },
        )
        full_coll = analysis.parse_collectives(compiled.as_text(), mesh.size)
        record["full_compile_collectives"] = full_coll.counts

        if with_probes and not multi:
            cost, probes = _probe_costs(cfg, shape, mesh, plan)
            roof = analysis.roofline_from_cost(cost, cell.model_flops_per_device)
            record["cost"] = {
                "flops_per_device": cost.flops,
                "bytes_per_device": cost.bytes_accessed,
                "wire_bytes_per_device": cost.wire_bytes,
                "collective_counts": cost.collective_counts,
            }
            record["roofline"] = {
                "compute_s": roof.compute_s,
                "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "bound": roof.bound,
                "step_s": roof.step_s,
                "model_flops_per_device": roof.model_flops,
                "useful_flops_ratio": roof.useful_flops_ratio,
                "mfu": roof.mfu,
            }
    except Exception as exc:  # noqa: BLE001
        record.update(status="error", error=repr(exc),
                      traceback=traceback.format_exc())
    return record


def cell_list():
    out = []
    for arch in configs.ARCH_NAMES:
        for shape in ALL_SHAPES:
            out.append((arch, shape.name))
    return out


def artifact_path(arch, shape, mesh_kind):
    d = os.path.abspath(os.path.join(ARTIFACT_DIR, mesh_kind))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = [(a, s) for a, s in cell_list()
            if (args.arch is None or a == args.arch)
            and (args.shape is None or s == args.shape)]

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch, shape in todo:
            path = artifact_path(arch, shape, mesh_kind)
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    rec = json.load(f)
            else:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind,
                               with_probes=not args.no_probes)
                rec["wall_s"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            st = rec["status"]
            n_ok += st == "ok"; n_skip += st == "skipped"; n_err += st == "error"
            extra = ""
            if st == "ok" and "roofline" in rec:
                r = rec["roofline"]
                extra = (f" bound={r['bound']} step={r['step_s']*1e3:.1f}ms "
                         f"mfu={r['mfu']:.3f}")
            if st == "ok":
                extra += f" peak={rec['memory']['peak_estimate_gb']:.1f}GB"
            if st == "error":
                extra = " " + rec["error"][:120]
            print(f"[{mesh_kind:6s}] {arch:22s} {shape:12s} {st:7s}"
                  f"{extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
