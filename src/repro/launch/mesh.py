"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before jax
initializes.
"""

from __future__ import annotations

import jax

from repro.sharding.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Best-effort mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
