"""End-to-end LM training as a Launchpad program.

Topology (the paper's patterns composed):

    data (CourierNode × N, prefetching pipeline shards)
      -> learner (MeshWorkerNode: pjit train loop, self-checkpointing)
      -> evaluator (PyNode: pulls params, reports eval loss)

The learner is a *stateful node in the paper-§6 sense*: on restart it
restores from its latest checkpoint and continues; data nodes and the
evaluator are stateless and just restart.

    PYTHONPATH=src python -m repro.launch.train --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced
    PYTHONPATH=src python -m repro.launch.train --preset lm100m --steps 300
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro import configs, core as lp
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, make_source
from repro.models.config import ATTN, ModelConfig
from repro.sharding import ShardingCtx, use_sharding
from repro.sharding.rules import batch_spec, param_sharding
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainConfig, make_train_state,
                                    make_train_step)

# A self-contained ~100M-param preset (brief: "train ~100M model").
LM100M = ModelConfig(
    name="lm100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
    pattern=(ATTN,), tie_embeddings=True)

LM_TINY = ModelConfig(
    name="lm-tiny", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
    pattern=(ATTN,), tie_embeddings=True)

PRESETS = {"lm100m": LM100M, "tiny": LM_TINY}


class DataNode:
    """Serves host-sharded batches from the pipeline (prefetched)."""

    def __init__(self, data_cfg: DataConfig, host_id: int, num_hosts: int):
        self._pf = Prefetcher(make_source(data_cfg, host_id, num_hosts),
                              depth=4)

    def next_batch(self):
        return next(self._pf)


class Learner:
    """SPMD learner: pjit train step over the node's mesh; checkpoints and
    serves params. Restores itself after restarts (paper §6)."""

    def __init__(self, model_cfg, train_cfg, data_nodes, ckpt_dir,
                 total_steps, ckpt_every=50, log_every=10, mesh=None):
        import jax
        import jax.numpy as jnp

        self._cfg = model_cfg
        self._data = data_nodes
        self._total = total_steps
        self._ckpt_every = ckpt_every
        self._log_every = log_every
        self._mesh = mesh
        self._mgr = CheckpointManager(ckpt_dir, keep=2)
        self._jnp = jnp

        params, opt = make_train_state(model_cfg, jax.random.key(0))
        self._start_step = 0
        step0, restored = self._mgr.restore_latest(
            {"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            self._start_step = step0
            print(f"learner: restored checkpoint at step {step0}")
        if mesh is not None:
            p_sh = param_sharding(params, mesh)
            o_sh = param_sharding(opt, mesh)
            params = jax.tree.map(jax.device_put, params, p_sh)
            opt = jax.tree.map(jax.device_put, opt, o_sh)
        self._params, self._opt = params, opt
        self._step_fn = jax.jit(make_train_step(model_cfg, train_cfg),
                                donate_argnums=(0, 1))
        self._latest_loss = float("nan")

    # -- courier-exposed -----------------------------------------------------
    def get_params(self):
        import jax
        return jax.tree.map(np.asarray, self._params)

    def status(self):
        return {"loss": self._latest_loss}

    # -- main loop -------------------------------------------------------------
    def run(self):
        import jax.numpy as jnp
        ctx = lp.get_current_context()
        dp = (ShardingCtx(self._mesh) if self._mesh is not None else None)
        t0 = time.time()
        losses = []
        step = self._start_step
        with use_sharding(dp):
            while step < self._total and not ctx.should_stop:
                shards = [d.next_batch() for d in self._data]
                batch = {k: np.concatenate([s[k] for s in shards])
                         for k in shards[0]}
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                self._params, self._opt, metrics = self._step_fn(
                    self._params, self._opt, batch)
                step += 1
                self._latest_loss = float(metrics["loss"])
                losses.append(self._latest_loss)
                if step % self._log_every == 0:
                    rate = self._log_every / max(time.time() - t0, 1e-9)
                    t0 = time.time()
                    print(f"step {step:5d} loss={self._latest_loss:7.4f} "
                          f"lr={float(metrics['lr']):.2e} "
                          f"gnorm={float(metrics['grad_norm']):6.3f} "
                          f"{rate:5.2f} steps/s", flush=True)
                if step % self._ckpt_every == 0:
                    self._mgr.save(step, {"params": self._params,
                                          "opt": self._opt})
        self._mgr.save(step, {"params": self._params, "opt": self._opt},
                       blocking=True)
        first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
        last = np.mean(losses[-10:])
        print(f"learner done at step {step}: loss {first:.4f} -> {last:.4f}")
        lp.stop_program()


class Evaluator:
    """Pulls params periodically and scores a held-out stream."""

    def __init__(self, learner, model_cfg, data_cfg, every_s=5.0):
        self._learner = learner
        self._cfg = model_cfg
        self._src = iter(make_source(dataclasses.replace(data_cfg, seed=999)))
        self._every = every_s

    def run(self):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer
        ctx = lp.get_current_context()
        while not ctx.should_stop:
            ctx.wait_for_stop(self._every)
            if ctx.should_stop:
                return
            params = jax.tree.map(jnp.asarray, self._learner.get_params())
            batch = next(self._src)
            loss, _ = transformer.loss_fn(
                self._cfg, params,
                {k: jnp.asarray(v) for k, v in batch.items()})
            print(f"  eval loss: {float(loss):.4f}", flush=True)


def build_program(model_cfg: ModelConfig, *, steps: int, ckpt_dir: str,
                  batch_size: int = 16, seq_len: int = 64,
                  num_data_nodes: int = 2, num_micro: int = 1,
                  mesh_shape=None, with_eval: bool = True) -> lp.Program:
    data_cfg = DataConfig(seq_len=seq_len,
                          batch_size=batch_size // num_data_nodes,
                          vocab_size=model_cfg.vocab_size)
    train_cfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=steps),
        num_microbatches=num_micro)

    p = lp.Program(f"train-{model_cfg.name}")
    with p.group("data"):
        data = [p.add_node(lp.CourierNode(DataNode, data_cfg, i,
                                          num_data_nodes))
                for i in range(num_data_nodes)]
    with p.group("learner"):
        learner = p.add_node(lp.MeshWorkerNode(
            Learner, model_cfg, train_cfg, data, ckpt_dir, steps))
    if with_eval:
        with p.group("eval"):
            p.add_node(lp.PyNode(Evaluator, learner, model_cfg, data_cfg))
    return p


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of --arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2,1 -> data=2,model=1 (needs devices)")
    args = ap.parse_args(argv)

    if args.arch:
        model_cfg = (configs.get_reduced(args.arch) if args.reduced
                     else configs.get(args.arch))
    else:
        model_cfg = PRESETS[args.preset]

    program = build_program(model_cfg, steps=args.steps,
                            ckpt_dir=args.ckpt_dir,
                            batch_size=args.batch_size,
                            seq_len=args.seq_len)
    resources = {}
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        resources["learner"] = {"mesh": shape,
                                "axes": ("data", "model")[: len(shape)]}
    print(program)
    launcher = lp.ThreadLauncher(
        restart_policy=lp.RestartPolicy(max_restarts=2))
    launcher.launch(program, resources or None)
    launcher.wait()
    if launcher.fatal_failures:
        raise SystemExit(f"fatal failure: {launcher.fatal_failures[0]}")


if __name__ == "__main__":
    main()
