"""End-to-end LM training as a Launchpad program — on the elastic fabric.

Topology (the paper's patterns composed, surviving worker churn):

    registry (CourierNode: membership + heartbeats, the control plane)
    data (CourierNode × N, prefetching pipeline shards)
      -> learners (fabric workers: chief aggregates peer gradients via
         hedged_map quorum, publishes {params, opt, ef} to the versioned
         ModelStore in ckpt_dir every --publish-every steps)
      <- supervisor (PyNode: spawns the learner fleet, respawns dead
         workers under RestartPolicy backoff; a respawned chief restores
         the last *published* version — step loss <= publish interval)
    evaluator (PyNode: pulls published versions from the store, reports
         eval loss — never an ad-hoc RPC params snapshot)

The learner is a *stateful node in the paper-§6 sense*: on restart it
restores from the latest published version and continues; data nodes and
the evaluator are stateless and just restart.

    PYTHONPATH=src python -m repro.launch.train --steps 200
    PYTHONPATH=src python -m repro.launch.train --learners 2 --steps 300
    PYTHONPATH=src python -m repro.launch.train --learners 2 --kill-after 3
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Optional

import numpy as np

from repro import configs, core as lp
from repro.ckpt.checkpoint import ModelStore
from repro.data.pipeline import DataConfig, Prefetcher, make_source
from repro.models.config import ATTN, ModelConfig
from repro.train.fabric import (ChaosNode, FabricConfig, LearnerWorker,
                                ThreadWorkerSpawner, TrainSupervisor)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig, make_grad_fn

# A self-contained ~100M-param preset (brief: "train ~100M model").
LM100M = ModelConfig(
    name="lm100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
    pattern=(ATTN,), tie_embeddings=True)

LM_TINY = ModelConfig(
    name="lm-tiny", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
    pattern=(ATTN,), tie_embeddings=True)

PRESETS = {"lm100m": LM100M, "tiny": LM_TINY}


class DataNode:
    """Serves host-sharded batches from the pipeline (prefetched)."""

    def __init__(self, data_cfg: DataConfig, host_id: int, num_hosts: int):
        self._pf = Prefetcher(make_source(data_cfg, host_id, num_hosts),
                              depth=4)

    def next_batch(self):
        return next(self._pf)


class LMTask:
    """The fabric task for LM pretraining: transformer loss + AdamW."""

    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig):
        self._model_cfg = model_cfg
        self.optimizer = train_cfg.optimizer
        self._compute = make_grad_fn(model_cfg, train_cfg)

    def init_params(self, key):
        from repro.models import transformer
        return transformer.init_params(self._model_cfg, key)

    def grad_fn(self, params, batch):
        loss, _aux, grads = self._compute(params, batch)
        return loss, grads


def _data_batch_fn(data_nodes):
    """Learner batch source over its assigned data-node shard(s); errors
    return None so the learner retries while a data node restarts."""
    def fn():
        try:
            shards = [d.next_batch() for d in data_nodes]
            return {k: np.concatenate([s[k] for s in shards])
                    for k in shards[0]}
        except Exception:  # noqa: BLE001
            return None
    return fn


class FleetSupervisor:
    """PyNode wrapper: hosts the learner fleet on a ThreadWorkerSpawner
    and runs the TrainSupervisor loop until the chief reports done."""

    def __init__(self, registry, data_nodes, model_cfg: ModelConfig,
                 train_cfg: TrainConfig, fab_cfg: FabricConfig,
                 store_dir: str, learners: int = 1, mesh_shape=None,
                 spawn_grace_s: float = 30.0):
        self._registry = registry
        self._data = list(data_nodes)
        self._task = LMTask(model_cfg, train_cfg)
        self._fab_cfg = fab_cfg
        self._store_dir = store_dir
        self._learners = learners
        self._mesh_shape = mesh_shape
        self._spawn_grace_s = spawn_grace_s

    def _make_mesh(self):
        if self._mesh_shape is None:
            return None
        from repro.sharding.compat import make_mesh
        names = ("data", "model")[: len(self._mesh_shape)]
        return make_mesh(tuple(self._mesh_shape), names)

    def run(self):
        spawner = ThreadWorkerSpawner()
        n_learners = self._learners

        def spawn_fn(name: str):
            idx = int(name.rsplit("-", 1)[1])
            shard = self._data[idx::n_learners] or [
                self._data[idx % len(self._data)]]
            batch_fn = _data_batch_fn(shard)
            mesh = self._make_mesh()
            spawner.spawn(name, lambda n, ep: LearnerWorker(
                self._task, batch_fn, self._store_dir, self._registry,
                self._fab_cfg, name=n, chief=(idx == 0), mesh=mesh,
                endpoint=ep))

        sup = TrainSupervisor(
            self._registry, spawn_fn, expected={"learner": n_learners},
            policy=lp.RestartPolicy(max_restarts=5, backoff_s=0.05),
            spawn_grace_s=self._spawn_grace_s,
            total_steps=self._fab_cfg.total_steps)
        try:
            sup.run()
        finally:
            spawner.stop_all()


class Evaluator:
    """Scores published versions from the ModelStore on a held-out
    stream — always a consistent, durable snapshot."""

    def __init__(self, store_dir: str, model_cfg: ModelConfig,
                 data_cfg: DataConfig, every_s: float = 5.0):
        self._store_dir = store_dir
        self._cfg = model_cfg
        self._src = iter(make_source(dataclasses.replace(data_cfg, seed=999)))
        self._every = every_s

    def run(self):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer
        ctx = lp.get_current_context()
        store = ModelStore(self._store_dir)
        like = transformer.init_params(self._cfg, jax.random.key(0))
        seen: Optional[int] = None
        while not ctx.should_stop:
            ctx.wait_for_stop(self._every)
            if ctx.should_stop:
                return
            try:
                v = store.latest_version()
                if v is None or v == seen:
                    continue
                params = store.load_version(v, like={"params": like})["params"]
                seen = v
            except Exception:  # noqa: BLE001 - version GC'd mid-read
                continue
            batch = next(self._src)
            loss, _ = transformer.loss_fn(
                self._cfg, jax.tree.map(jnp.asarray, params),
                {k: jnp.asarray(v_) for k, v_ in batch.items()})
            print(f"  eval v{v} loss: {float(loss):.4f}", flush=True)


def build_program(model_cfg: ModelConfig, *, steps: int, ckpt_dir: str,
                  batch_size: int = 16, seq_len: int = 64,
                  num_data_nodes: int = 2, num_micro: int = 1,
                  mesh_shape=None, with_eval: bool = True,
                  learners: int = 1, publish_every: int = 50,
                  kill_after: Optional[float] = None,
                  # Generous TTL: a first-step jit trace can starve the
                  # heartbeat thread for seconds; that is a stall, not a
                  # death, and should not trigger a respawn.
                  registry_ttl_s: float = 10.0,
                  heartbeat_s: float = 0.2) -> lp.Program:
    data_cfg = DataConfig(seq_len=seq_len,
                          batch_size=batch_size // num_data_nodes,
                          vocab_size=model_cfg.vocab_size)
    train_cfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=steps),
        num_microbatches=num_micro)
    fab_cfg = FabricConfig(total_steps=steps, batch_size=batch_size,
                           publish_every=publish_every,
                           heartbeat_s=heartbeat_s)

    p = lp.Program(f"train-{model_cfg.name}")
    with p.group("registry"):
        registry = p.add_node(lp.CourierNode(lp.Registry,
                                             ttl_s=registry_ttl_s))
    with p.group("data"):
        data = [p.add_node(lp.CourierNode(DataNode, data_cfg, i,
                                          num_data_nodes))
                for i in range(num_data_nodes)]
    with p.group("supervisor"):
        p.add_node(lp.PyNode(FleetSupervisor, registry, data, model_cfg,
                             train_cfg, fab_cfg, ckpt_dir,
                             learners=learners, mesh_shape=mesh_shape))
    if kill_after is not None:
        with p.group("chaos"):
            p.add_node(lp.PyNode(
                ChaosNode, registry,
                [("kill", "learner-0", kill_after, 0.0)]))
    if with_eval:
        with p.group("eval"):
            p.add_node(lp.PyNode(Evaluator, ckpt_dir, model_cfg, data_cfg))
    return p


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of --arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--learners", type=int, default=1,
                    help="data-parallel learner count (chief = learner-0)")
    ap.add_argument("--publish-every", type=int, default=50,
                    help="ModelStore publish interval = max step loss on "
                         "a learner death")
    ap.add_argument("--kill-after", type=float, default=None,
                    help="chaos demo: kill the chief learner this many "
                         "seconds in; the supervisor restores it from the "
                         "last published version")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2,1 -> data=2,model=1 (needs devices)")
    args = ap.parse_args(argv)

    if args.arch:
        model_cfg = (configs.get_reduced(args.arch) if args.reduced
                     else configs.get(args.arch))
    else:
        model_cfg = PRESETS[args.preset]

    mesh_shape = (tuple(int(x) for x in args.mesh.split(","))
                  if args.mesh else None)
    program = build_program(model_cfg, steps=args.steps,
                            ckpt_dir=args.ckpt_dir,
                            batch_size=args.batch_size,
                            seq_len=args.seq_len,
                            learners=args.learners,
                            publish_every=args.publish_every,
                            kill_after=args.kill_after,
                            mesh_shape=mesh_shape)
    print(program)
    launcher = lp.ThreadLauncher(
        restart_policy=lp.RestartPolicy(max_restarts=2))
    launcher.launch(program)
    launcher.wait()
    if launcher.fatal_failures:
        raise SystemExit(f"fatal failure: {launcher.fatal_failures[0]}")


if __name__ == "__main__":
    main()
