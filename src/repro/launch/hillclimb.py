import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver: re-lower one cell under variant settings and
report the roofline-term deltas (EXPERIMENTS.md §Perf).

A variant is (plan overrides + model-module flags). Each run produces the
same probe-extrapolated cost record as the dry-run baseline, so before/
after comparisons are apples-to-apples.

    python -m repro.launch.hillclimb --arch qwen3-8b --shape train_4k \
        --variant attn_bf16
    python -m repro.launch.hillclimb --list
"""

import argparse
import dataclasses
import json
import time

from repro import configs
from repro.launch import cells as cells_lib

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "hillclimb")

# variant name -> dict(plan={...}, flags={...})
VARIANTS = {
    "baseline": {},
    # --- memory-term levers --------------------------------------------------
    "attn_bf16": {"flags": {"attention.LOGITS_DTYPE": "bfloat16"}},
    "ssm_bf16": {"flags": {"ssm.SCAN_DTYPE": "bfloat16"}},
    "remat_none": {"plan": {"remat": "none"}},
    # --- collective-term levers -----------------------------------------------
    "micro1": {"plan": {"num_microbatches": 1}},
    "micro2": {"plan": {"num_microbatches": 2}},
    "micro4": {"plan": {"num_microbatches": 4}},
    "micro8": {"plan": {"num_microbatches": 8}},
    "no_resid_tp": {"plan": {"resid_tp": False}},
    "resid_tp": {"plan": {"resid_tp": True}},
    "norm_bf16": {"flags": {"layers.NORM_RESIDENT_DTYPE": "compute"}},
    # --- combinations ----------------------------------------------------------
    "attn_bf16+micro4": {"plan": {"num_microbatches": 4},
                         "flags": {"attention.LOGITS_DTYPE": "bfloat16"}},
    "ssm_bf16+micro2": {"plan": {"num_microbatches": 2},
                        "flags": {"ssm.SCAN_DTYPE": "bfloat16"}},
    "attn_bf16+ssm_bf16": {"flags": {"attention.LOGITS_DTYPE": "bfloat16",
                                     "ssm.SCAN_DTYPE": "bfloat16"}},
    "norm_bf16+attn_bf16": {"flags": {
        "layers.NORM_RESIDENT_DTYPE": "compute",
        "attention.LOGITS_DTYPE": "bfloat16"}},
    "norm_bf16+micro8": {"plan": {"num_microbatches": 8},
                         "flags": {"layers.NORM_RESIDENT_DTYPE": "compute"}},
    "all_bf16": {"flags": {
        "layers.NORM_RESIDENT_DTYPE": "compute",
        "attention.LOGITS_DTYPE": "bfloat16",
        "ssm.SCAN_DTYPE": "bfloat16"}},
    "all_bf16+micro8": {"plan": {"num_microbatches": 8}, "flags": {
        "layers.NORM_RESIDENT_DTYPE": "compute",
        "attention.LOGITS_DTYPE": "bfloat16",
        "ssm.SCAN_DTYPE": "bfloat16"}},
}


def _set_flag(dotted: str, value):
    import importlib
    mod_name, attr = dotted.rsplit(".", 1)
    mod = importlib.import_module(f"repro.models.{mod_name}")
    old = getattr(mod, attr)
    setattr(mod, attr, value)
    return mod, attr, old


def run_variant(arch: str, shape_name: str, variant: str) -> dict:
    from repro.launch import dryrun  # late import: needs XLA_FLAGS set
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis

    spec = VARIANTS[variant]
    cfg = configs.get(arch)
    shape = cells_lib.SHAPES[shape_name]
    mesh = make_production_mesh()

    plan = cells_lib.plan_cell(cfg, shape, mesh)
    if spec.get("plan"):
        plan = dataclasses.replace(plan, **spec["plan"])

    restore = []
    try:
        for dotted, value in (spec.get("flags") or {}).items():
            restore.append(_set_flag(dotted, value))

        t0 = time.time()
        cell, compiled, _, _ = dryrun._compile_cell(cfg, shape, mesh, plan)
        ma = compiled.memory_analysis()
        cost, _ = dryrun._probe_costs(cfg, shape, mesh, plan)
        roof = analysis.roofline_from_cost(cost, cell.model_flops_per_device)
        rec = {
            "arch": arch, "shape": shape_name, "variant": variant,
            "plan": dataclasses.asdict(plan),
            "flags": spec.get("flags", {}),
            "wall_s": round(time.time() - t0, 1),
            "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes
                        - ma.alias_size_in_bytes) / 1e9,
            "cost": {"flops": cost.flops, "bytes": cost.bytes_accessed,
                     "wire": cost.wire_bytes,
                     "collectives": cost.collective_counts},
            "roofline": {"compute_s": roof.compute_s,
                         "memory_s": roof.memory_s,
                         "collective_s": roof.collective_s,
                         "bound": roof.bound, "step_s": roof.step_s,
                         "mfu": roof.mfu,
                         "useful": roof.useful_flops_ratio},
        }
    except Exception as exc:  # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name, "variant": variant,
               "status": "error", "error": repr(exc)}
    finally:
        for mod, attr, old in restore:
            setattr(mod, attr, old)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for k in VARIANTS:
            print(k)
        return
    os.makedirs(ART, exist_ok=True)
    rec = run_variant(args.arch, args.shape, args.variant)
    path = os.path.join(
        ART, f"{args.arch}__{args.shape}__{args.variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline")
    if r:
        print(f"{args.arch} {args.shape} {args.variant}: "
              f"bound={r['bound']} ct={r['compute_s']:.3f} "
              f"mt={r['memory_s']:.3f} colt={r['collective_s']:.3f} "
              f"step={r['step_s']:.3f}s mfu={r['mfu']:.4f} "
              f"peak={rec['peak_gb']:.1f}GB")
    else:
        print(rec)


if __name__ == "__main__":
    main()
