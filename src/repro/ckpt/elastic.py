"""Elastic resharding: restore a checkpoint onto a *different* mesh.

The failure-recovery contract (paper §6 + our scale-out): a learner that
comes back on a smaller/larger pod slice restores the same logical state.
Because checkpoints are full logical arrays and sharding specs are derived
from parameter *paths* (not from the mesh they were saved under), restoring
onto a new mesh is just re-running the rules against the new mesh and
device_put-ting each leaf.

The whole learner state reshards as one tree — params, optimizer moments,
*and* the ``grad_compression`` int8 error-feedback residual. The residual
is genuine training state: dropping it across a shrink/grow restore would
silently reintroduce the quantization bias that error feedback exists to
cancel. Checkpoints published before the residual existed still restore
via ``fill_missing`` (the caller's zero residual stands in).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.ckpt import checkpoint
from repro.sharding.rules import param_sharding


def reshard(tree, new_mesh: Mesh):
    """Re-place a (host or device) pytree under rules for ``new_mesh``."""
    shardings = param_sharding(tree, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), tree, shardings)


def restore_elastic(directory: str, like, new_mesh: Optional[Mesh] = None,
                    fill_missing: bool = False):
    """Restore a checkpoint; if ``new_mesh`` is given, shard onto it.

    ``fill_missing=True`` tolerates schema growth: leaves absent from the
    checkpoint (e.g. an error-feedback residual added after the version
    was published) come from ``like`` instead of raising.
    """
    tree = checkpoint.restore(directory, like=like, fill_missing=fill_missing)
    if new_mesh is None:
        return tree
    return reshard(tree, new_mesh)
