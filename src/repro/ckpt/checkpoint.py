"""Sharded checkpointing with async save (paper §6's self-restoring nodes).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` describing the tree (and, for published versions, a
``meta.json`` with step / config hash / eval metrics). Leaves are written
from host memory (``jax.device_get``); restore can re-place them under any
sharding — that, plus mesh-shape-agnostic specs, is what makes restarts
*elastic* (see ``repro.ckpt.elastic``).

Atomicity & durability: writes land in ``step_<N>.tmp`` and are renamed
only when complete, so a node killed mid-save never corrupts its latest
checkpoint, and a replica restoring mid-write never sees a partial one
(``all_steps``/``restore_latest`` additionally skip any directory without a
readable manifest — e.g. debris from an interrupted rename dance). Durable
saves (``save(..., durable=True)``, used by ``publish``) fsync every file
and the directory before the rename, so a published model version survives
power loss, not just process death.

``ModelStore`` builds the serving-side view on the same layout: versions
are published atomically with metadata, replicas load them by id, and GC
never collects a version a live replica reports serving (``retain_fn``).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from concurrent import futures
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        parts = []
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out, treedef


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def config_hash(cfg: Any) -> str:
    """Stable short hash of a model config (dataclass or anything
    repr-able) — stored in version metadata so a replica can refuse to
    hot-swap weights built for a different architecture."""
    import dataclasses as dc
    if dc.is_dataclass(cfg) and not isinstance(cfg, type):
        blob = json.dumps(dc.asdict(cfg), sort_keys=True, default=str)
    else:
        blob = repr(cfg)
    return hashlib.blake2b(blob.encode(), digest_size=8).hexdigest()


def save(tree, directory: str, metadata: Optional[dict] = None,
         durable: bool = False) -> None:
    """Write ``tree`` under ``directory`` atomically (tmp dir + rename).

    ``durable=True`` additionally fsyncs every leaf file, the manifest, the
    tmp dir, and the parent dir around the rename — required for published
    model versions that must survive machine crash, optional for periodic
    train checkpoints where losing the very last one is acceptable.

    Concurrent writers of the *same* directory are safe (last writer
    wins): each writes its own uniquely-named tmp dir, and the rename
    dance retries around a sibling landing first. This happens in chaos
    recovery — a killed learner's in-flight publish can overlap its
    replacement's publish of the same step; both trees are complete
    states, so either winning is correct.
    """
    tag = f".tmp.{os.getpid()}.{threading.get_ident()}"
    tmp = directory + tag
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    named, _ = _flatten(tree)
    manifest = []
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            if durable:
                f.flush()
                os.fsync(f.fileno())
        manifest.append({"name": name, "file": fname,
                         "dtype": str(arr.dtype), "shape": list(arr.shape)})
    if metadata is not None:
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(metadata, f)
            if durable:
                f.flush()
                os.fsync(f.fileno())
    # The manifest lands last: a directory with a manifest is complete by
    # construction, which is what lets readers treat "no manifest" as
    # "half-written — skip".
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        if durable:
            f.flush()
            os.fsync(f.fileno())
    if durable:
        _fsync_dir(tmp)
    # Overwrite dance: park any existing dir aside so there is never a
    # moment where ``directory`` exists half-built — readers see either
    # old or new, never a partial mix. Retried because a concurrent
    # publisher of the same step may land between our park and replace.
    for attempt in range(8):
        try:
            os.replace(tmp, directory)   # succeeds iff directory absent
            break
        except OSError:
            trash = directory + f".old{tag}.{attempt}"
            try:
                os.rename(directory, trash)
            except FileNotFoundError:
                continue                 # sibling already parked it
            shutil.rmtree(trash, ignore_errors=True)
    else:
        raise OSError(f"could not atomically land {directory} "
                      "(concurrent writers thrashing)")
    if durable:
        _fsync_dir(os.path.dirname(os.path.abspath(directory)))


def is_complete(directory: str) -> bool:
    """A checkpoint dir is complete iff its manifest is present and parses
    — the write protocol guarantees the manifest lands last."""
    try:
        with open(os.path.join(directory, "manifest.json")) as f:
            json.load(f)
        return True
    except (OSError, ValueError):
        return False


def load_metadata(directory: str) -> dict:
    """The ``meta.json`` written at publish time ({} if absent)."""
    try:
        with open(os.path.join(directory, "meta.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def restore(directory: str, like=None, shardings=None,
            fill_missing: bool = False):
    """Load a checkpoint. With ``like`` (a pytree), returns that structure;
    otherwise returns a flat {name: array} dict. ``shardings`` (pytree or
    flat dict) re-places leaves onto devices.

    ``fill_missing=True`` substitutes ``like``'s own leaf for any name the
    checkpoint lacks instead of raising — used by elastic restores where
    the state schema has grown since the version was published (e.g. a
    checkpoint written before the error-feedback residual existed restores
    with the caller's zero-initialized residual).
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {e["name"]: np.load(os.path.join(directory, e["file"]))
            for e in manifest}
    if like is None:
        return flat
    named, treedef = _flatten(like)
    leaves = []
    shard_named = None
    if shardings is not None:
        shard_named = dict(_flatten(shardings)[0])
    for name, ref in named:
        if name not in flat:
            if fill_missing:
                leaves.append(np.asarray(jax.device_get(ref)))
                continue
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{name}: ckpt shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        if shard_named is not None and name in shard_named:
            arr = jax.device_put(arr, shard_named[name])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Periodic, async, retention-limited checkpoints for stateful nodes.

    ``retain_fn`` (optional) returns the set of step ids that are pinned —
    e.g. versions live serve replicas report serving (read off the
    Registry's version table). ``_gc`` never deletes a retained step, no
    matter how old, so a rollout can always roll *back* to the version the
    fleet was on.
    """

    def __init__(self, directory: str, keep: int = 3,
                 retain_fn: Optional[Callable[[], Iterable[int]]] = None):
        self.directory = directory
        self.keep = keep
        self._retain_fn = retain_fn
        os.makedirs(directory, exist_ok=True)
        self._pool = futures.ThreadPoolExecutor(max_workers=1,
                                                thread_name_prefix="ckpt")
        self._pending: Optional[futures.Future] = None
        self._lock = threading.Lock()

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        """Complete checkpoints only: half-written dirs (no manifest yet —
        in-flight background save, or debris from a crash mid-write) are
        invisible to readers."""
        steps = []
        for name in os.listdir(self.directory):
            if (name.startswith("step_") and ".tmp" not in name
                    and ".old" not in name):
                try:
                    step = int(name[5:])
                except ValueError:
                    continue
                if is_complete(os.path.join(self.directory, name)):
                    steps.append(step)
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree, blocking: bool = False,
             metadata: Optional[dict] = None, durable: bool = False) -> None:
        # Snapshot to host now (cheap on CPU; on TPU this is the D2H copy),
        # write in the background so the train loop keeps stepping. The
        # background write inherits the same tmp-dir + rename protocol, so
        # a reader (or a crash) mid-write never observes a partial step.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(host_tree, self._step_dir(step), metadata=metadata,
                 durable=durable)
            self._gc()

        with self._lock:
            if self._pending is not None:
                self._pending.result()  # one in flight at a time
            self._pending = self._pool.submit(_write)
            if blocking:
                self._pending.result()

    def publish(self, step: int, tree, metadata: Optional[dict] = None,
                blocking: bool = True) -> None:
        """Atomic, *durable* publish of a model version: fsync every file
        and directory around the rename. Blocking by default — a rollout
        must not announce a version whose bytes may still be in page
        cache."""
        self.save(step, tree, blocking=blocking, metadata=dict(metadata or {}),
                  durable=True)

    def metadata(self, step: int) -> dict:
        return load_metadata(self._step_dir(step))

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self._step_dir(step), like, shardings)

    def _gc(self) -> None:
        retained = set()
        if self._retain_fn is not None:
            try:
                retained = {int(s) for s in self._retain_fn()}
            except Exception:  # noqa: BLE001 - can't read pins: delete nothing
                return
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            if s in retained:
                continue
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


class ModelStore(CheckpointManager):
    """Versioned model weights for the serve fabric, on the checkpoint
    layout (a version id *is* a step id — the train loop publishes, the
    fleet serves).

    The store itself holds no rollout state: which replica serves which
    version lives in the Registry's membership table, which is what makes
    a crashed RolloutController re-derivable. Wire ``retain_fn`` to the
    registry's version table so GC can never collect a version that is
    still live on some replica.
    """

    def publish_version(self, version: int, tree,
                        metadata: Optional[dict] = None) -> None:
        self.publish(int(version), tree, metadata=metadata, blocking=True)

    def version_dir(self, version: int) -> str:
        """Path of a published version — the directory elastic restores
        hand to ``ckpt.elastic.restore_elastic``."""
        return self._step_dir(int(version))

    def load_version(self, version: int, like=None, shardings=None):
        path = self._step_dir(int(version))
        if not is_complete(path):
            raise FileNotFoundError(
                f"model version {version} not published (or incomplete) "
                f"in {self.directory}")
        return restore(path, like, shardings)

    def versions(self) -> list[int]:
        return self.all_steps()

    def latest_version(self) -> Optional[int]:
        return self.latest_step()
