"""Sharded checkpointing with async save (paper §6's self-restoring nodes).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` describing the tree. Leaves are written from host memory
(``jax.device_get``); restore can re-place them under any sharding — that,
plus mesh-shape-agnostic specs, is what makes restarts *elastic* (see
``repro.ckpt.elastic``).

Atomicity: writes land in ``step_<N>.tmp`` and are renamed only when
complete, so a node killed mid-save never corrupts its latest checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent import futures
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        parts = []
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out, treedef


def save(tree, directory: str) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    named, _ = _flatten(tree)
    manifest = []
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest.append({"name": name, "file": fname,
                         "dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)


def restore(directory: str, like=None, shardings=None):
    """Load a checkpoint. With ``like`` (a pytree), returns that structure;
    otherwise returns a flat {name: array} dict. ``shardings`` (pytree or
    flat dict) re-places leaves onto devices."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {e["name"]: np.load(os.path.join(directory, e["file"]))
            for e in manifest}
    if like is None:
        return flat
    named, treedef = _flatten(like)
    leaves = []
    shard_named = None
    if shardings is not None:
        shard_named = dict(_flatten(shardings)[0])
    for name, ref in named:
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{name}: ckpt shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        if shard_named is not None and name in shard_named:
            arr = jax.device_put(arr, shard_named[name])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Periodic, async, retention-limited checkpoints for stateful nodes."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = futures.ThreadPoolExecutor(max_workers=1,
                                                thread_name_prefix="ckpt")
        self._pending: Optional[futures.Future] = None
        self._lock = threading.Lock()

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree, blocking: bool = False) -> None:
        # Snapshot to host now (cheap on CPU; on TPU this is the D2H copy),
        # write in the background so the train loop keeps stepping.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(host_tree, self._step_dir(step))
            self._gc()

        with self._lock:
            if self._pending is not None:
                self._pending.result()  # one in flight at a time
            self._pending = self._pool.submit(_write)
            if blocking:
                self._pending.result()

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self._step_dir(step), like, shardings)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
