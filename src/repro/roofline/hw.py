"""TPU v5e hardware constants (the TARGET platform; container runs CPU)."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link (~50 GB/s); single-link basis

CHIPS_PER_POD = 256
HBM_BYTES = 16 * 1024**3      # 16 GiB per chip
