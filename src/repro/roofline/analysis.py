"""Roofline terms from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_wire_bytes_per_device / ICI_LINK_BW

``cost_analysis()`` reports **per-device** FLOPs/bytes but counts
while-loop (lax.scan) bodies ONCE, so production configs (scan over
layers, scan over microbatches) are costed via an *unrolled depth probe*:
compile the same step with 1 and 2 unrolled superblocks, take the delta as
per-superblock cost, and scale analytically (see ``extrapolate``).

Collective bytes are not in cost_analysis at all — we parse the optimized
HLO text and convert each collective's result shape + replica-group size
into ring-algorithm wire bytes per device.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.roofline import hw

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,4096,512]{2,1,0} all-gather(%x), ...
#        %ar = (f32[128]{0}, f32[64]{0}) all-reduce(%a, %b), ...
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9_]+\[[0-9,]*\][^)]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_BRACED_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    m = _GROUPS_BRACED_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    # per-device bytes by kind: result bytes and ring wire bytes
    result_bytes: dict
    wire_bytes: dict
    counts: dict

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    result_bytes = {k: 0.0 for k in _COLL_KINDS}
    wire = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shapes_str, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue  # counted at -start
        size = _shape_bytes(shapes_str)
        g = _group_size(line, num_devices)
        if g <= 1:
            continue
        # Ring-algorithm wire bytes per participating device. HLO shapes
        # are already per-device (SPMD-partitioned).
        if kind == "all-reduce":
            w = 2.0 * size * (g - 1) / g
        elif kind == "all-gather":
            w = size * (g - 1) / g            # size = gathered result
        elif kind == "reduce-scatter":
            w = size * (g - 1)                # size = scattered result shard
        elif kind == "all-to-all":
            w = size * (g - 1) / g
        else:  # collective-permute
            w = size
        result_bytes[kind] += size
        wire[kind] += w
        counts[kind] += 1
    return CollectiveStats(result_bytes, wire, counts)


@dataclasses.dataclass
class CellCost:
    """Per-device cost of one compiled step."""
    flops: float
    bytes_accessed: float
    wire_bytes: float
    collective_counts: dict

    def __sub__(self, other: "CellCost") -> "CellCost":
        return CellCost(
            self.flops - other.flops,
            self.bytes_accessed - other.bytes_accessed,
            self.wire_bytes - other.wire_bytes,
            {k: self.collective_counts.get(k, 0) - other.collective_counts.get(k, 0)
             for k in set(self.collective_counts) | set(other.collective_counts)})

    def scaled(self, f: float) -> "CellCost":
        return CellCost(self.flops * f, self.bytes_accessed * f,
                        self.wire_bytes * f,
                        {k: v * f for k, v in self.collective_counts.items()})

    def __add__(self, other: "CellCost") -> "CellCost":
        return CellCost(
            self.flops + other.flops,
            self.bytes_accessed + other.bytes_accessed,
            self.wire_bytes + other.wire_bytes,
            {k: self.collective_counts.get(k, 0) + other.collective_counts.get(k, 0)
             for k in set(self.collective_counts) | set(other.collective_counts)})


def cost_from_compiled(compiled, num_devices: int) -> CellCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text(), num_devices)
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=coll.total_wire_bytes,
        collective_counts=coll.counts)


def extrapolate(probe1: CellCost, probe2: CellCost, num_superblocks: float,
                micro_scale: float = 1.0) -> CellCost:
    """Depth extrapolation: per-superblock = probe2 - probe1 (probes are
    compiled with 1 and 2 unrolled superblocks and one microbatch);
    total = base + num_superblocks·per_sb, with the per-microbatch portion
    of the *base* FLOPs/bytes also scaled by ``micro_scale`` (the embedding
    + head compute runs once per microbatch, collectives for grads once per
    step — we approximate by scaling everything except the gradient
    all-reduce uniformly; exact for micro_scale=1)."""
    per_sb = probe2 - probe1
    base = probe1 - per_sb
    total = base.scaled(micro_scale) + per_sb.scaled(num_superblocks * micro_scale)
    # Gradient/optimizer collectives in `base` already happen once per step;
    # scaling them by micro_scale over-counts, but micro_scale corrections
    # only matter for FLOPs-dominated terms. Recorded as methodology note.
    return total


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS / (chips × peak × step_time) — roofline-model MFU."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops / hw.PEAK_FLOPS_BF16) / self.step_s


def roofline_from_cost(cost: CellCost, model_flops_per_device: float) -> Roofline:
    return Roofline(
        compute_s=cost.flops / hw.PEAK_FLOPS_BF16,
        memory_s=cost.bytes_accessed / hw.HBM_BW,
        collective_s=cost.wire_bytes / hw.ICI_LINK_BW,
        model_flops=model_flops_per_device,
        hlo_flops=cost.flops)
