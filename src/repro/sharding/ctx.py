"""Activation-sharding context.

Model code calls ``shard(x, 'dp', None, 'tp')`` at layer boundaries; the
logical axes are resolved against the active mesh (``'dp'`` expands to the
data-parallel axes — ``('pod','data')`` on the multi-pod mesh — and
``'tp'`` to the tensor-parallel axis). Outside any context (smoke tests,
examples on one CPU device) it is a no-op, so the same model code runs
everywhere.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_local = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    # Logical -> physical axis names.
    dp: tuple[str, ...] = ("data",)    # batch / fsdp axes
    tp: tuple[str, ...] = ("model",)   # tensor-parallel axes

    def resolve(self, logical) -> Optional[tuple[str, ...]]:
        if logical is None:
            return None
        if logical == "dp":
            out = tuple(a for a in self.dp if a in self.mesh.axis_names)
        elif logical == "tp":
            out = tuple(a for a in self.tp if a in self.mesh.axis_names)
        else:
            raise ValueError(f"unknown logical axis {logical!r}")
        return out or None

    def pspec(self, *logical) -> P:
        return P(*[self.resolve(l) for l in logical])

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*logical))


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_local, "ctx", None)


def get_mesh() -> Optional[Mesh]:
    ctx = current_ctx()
    return ctx.mesh if ctx else None


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardingCtx]):
    prev = getattr(_local, "ctx", None)
    _local.ctx = ctx
    try:
        yield
    finally:
        _local.ctx = prev


def shard(x, *logical):
    """with_sharding_constraint against the active mesh; no-op without one.

    Axes that don't divide the corresponding dim are dropped (right-to-left)
    so the same model code serves every cell — e.g. batch=1 long-context
    decode simply stays replicated on the DP axes.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    from repro.sharding.rules import fit_spec  # local: avoid import cycle
    spec = fit_spec(ctx.mesh, x.shape, [ctx.resolve(l) for l in logical])
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
