"""jax version compatibility helpers (mesh construction, shard_map).

Newer jax exposes ``jax.make_mesh(..., axis_types=...)`` and
``jax.shard_map(..., check_vma=...)``; older versions (e.g. 0.4.x) have
neither kwarg and keep shard_map under ``jax.experimental`` with the
``check_rep`` spelling. Route both constructions through here.
"""

from __future__ import annotations

from typing import Sequence


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis_types where the kwarg exists."""
    import jax
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    return jax.make_mesh(
        tuple(axis_shapes), tuple(axis_names),
        axis_types=(axis_type.Auto,) * len(axis_names))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the old experimental fallback."""
    import jax
    new_sm = getattr(jax, "shard_map", None)
    if new_sm is not None:
        return new_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)
