from repro.sharding.ctx import (ShardingCtx, current_ctx, get_mesh, shard,
                                use_sharding)
from repro.sharding.rules import batch_spec, param_sharding, spec_for_path

__all__ = [
    "ShardingCtx", "use_sharding", "current_ctx", "shard", "get_mesh",
    "param_sharding", "spec_for_path", "batch_spec",
]
