"""Collective matmul: overlap TP all-gather with compute (shard_map).

The XLA-inserted all-gather for a column-parallel matmul serializes
communication before compute. The ring formulation below (Wang et al.,
"Overlap communication with dependent computation", the standard TPU
collective-matmul) decomposes

    Y = X @ W,   X sharded over the TP axis on its contraction dim

into TP steps: each step matmuls the locally-held X shard against the
matching W rows while ``ppermute`` ships the next X shard around the ring
— communication rides the ICI while the MXU stays busy. On TPU the XLA
scheduler overlaps the ppermute send/recv of step i+1 with the dot of
step i (async collective-permute); wall-clock ≈ max(compute, comm) instead
of compute + comm.

Used as an opt-in replacement for the first FFN matmul (hillclimb lever);
validated against the plain einsum in tests on a host-device mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _ring_matmul(axis_name: str, tp: int, x_shard: jax.Array, w: jax.Array):
    """Inside shard_map. x_shard: [B, S, D/tp]; w: [D/tp·tp?, F/tp] — w holds
    this device's column shard with FULL D rows: [D, F/tp].

    Each step contributes x_shard_j @ w[rows_j] and rotates x. ``tp`` is the
    static tp-axis size, taken from the mesh by the caller (the ppermute
    permutation and loop trip count must be static; ``jax.lax.axis_size``
    does not exist on older jax).
    """
    idx = jax.lax.axis_index(axis_name)
    d_shard = x_shard.shape[-1]

    def rows(j):
        # Which D-rows of w the shard arriving at step s came from.
        return jax.lax.dynamic_slice_in_dim(w, j * d_shard, d_shard, axis=0)

    def body(s, carry):
        acc, x_cur = carry
        src = jnp.mod(idx + s, tp)          # owner of the shard we now hold
        acc = acc + jnp.einsum("bsd,df->bsf", x_cur,
                               rows(src).astype(x_cur.dtype))
        x_nxt = jax.lax.ppermute(
            x_cur, axis_name,
            [(i, (i - 1) % tp) for i in range(tp)])
        return acc, x_nxt

    acc = jnp.zeros(x_shard.shape[:-1] + (w.shape[-1],),
                    jnp.promote_types(x_shard.dtype, jnp.bfloat16))
    acc, _ = jax.lax.fori_loop(0, tp, body, (acc, x_shard))
    return acc.astype(x_shard.dtype)


def collective_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                      tp_axis: str = "model",
                      dp_axes=("data",)) -> jax.Array:
    """Y[B,S,F] = X[B,S,D] @ W[D,F], X feature-sharded over ``tp_axis``,
    W column-sharded — without a blocking X all-gather."""
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    from repro.sharding.compat import shard_map
    fn = shard_map(
        functools.partial(_ring_matmul, tp_axis, mesh.shape[tp_axis]),
        mesh=mesh,
        in_specs=(P(dp_spec, None, tp_axis), P(None, tp_axis)),
        out_specs=P(dp_spec, None, tp_axis),
        check_vma=False,  # fori_loop carry mixes varying/unvarying axes
    )
    return fn(x, w)
