"""Parameter/optimizer/batch sharding rules.

Scheme (single pod 16×16, axes ``("data","model")``):

  * 2-D weight sharding = FSDP('data') × TP('model'): column-parallel
    projections ``P('data','model')``, row-parallel ``P('model','data')``
    (Megatron layout + ZeRO-3-style weight sharding; XLA inserts the
    all-gathers at use and reduce-scatters for the grads).
  * Embedding: vocab-sharded rows over the FSDP axis (masked gather +
    all-reduce lookup); tied head resharded once per step in lm_logits.
  * MoE experts: ``P(None,'data','model')`` — expert dim replicated,
    2-D sharding inside each expert.
  * Multi-pod ``("pod","data","model")``: the pod axis is pure DP —
    params replicated across pods (no cross-DCN weight all-gathers on the
    critical path), gradients all-reduced over it.

Every rule is divisibility-checked against the actual dim; axes that don't
divide are dropped right-to-left, so tiny smoke configs simply replicate.
"""

from __future__ import annotations

import re
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, tuple[str, ...]]


def _axis_size(mesh: Mesh, entry: Axis) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, entry: Axis) -> Axis:
    """Drop axes (right to left) until the dim divides the axis product.
    Axes the mesh doesn't have (e.g. 'model' on a DP-only example mesh)
    are ignored."""
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    while axes:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if n > 1 and dim % n == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[:-1]
    return None


def fit_spec(mesh: Mesh, shape: Sequence[int], spec: Sequence[Axis]) -> P:
    assert len(shape) == len(spec), (shape, spec)
    return P(*[_fit(mesh, d, e) for d, e in zip(shape, spec)])


# (regex, spec builder taking ndim-agnostic core dims). Specs are for the
# *unstacked* tensor; a leading scan/stack dim gets None prepended.
_RULES: list[tuple[str, tuple[Axis, ...]]] = [
    # embeddings / head. The token table is vocab-(row-)sharded over the
    # FSDP axis — XLA partitions the lookup via masked-gather + all-reduce;
    # the tied-head reshard to P('model', None) happens explicitly in
    # layers.lm_logits so logits come out vocab-sharded from a local matmul.
    (r"embed/tokens$",             ("data", None)),
    (r"embed/head/kernel$",        ("data", "model")),
    (r"embed/conv_pos$",           (None, None, ("data", "model"))),
    # attention
    (r"attn/w[qkv]/kernel$",       ("data", "model")),
    (r"attn/w[qkv]/bias$",         ("model",)),
    (r"attn/wo/kernel$",           ("model", "data")),
    (r"attn/wo/bias$",             (None,)),
    # dense mlp
    (r"mlp/w_(gate|up)/kernel$",   ("data", "model")),
    (r"mlp/w_(gate|up)/bias$",     ("model",)),
    (r"mlp/w_down/kernel$",        ("model", "data")),
    (r"mlp/w_down/bias$",          (None,)),
    # moe
    (r"mlp/router/kernel$",        ("data", None)),
    (r"mlp/router/bias$",          (None,)),
    (r"mlp/w_(gate|up)$",          (None, "data", "model")),
    (r"mlp/w_down$",               (None, "model", "data")),
    # rg-lru
    (r"rglru/in_(x|gate)/kernel$", ("data", "model")),
    (r"rglru/in_(x|gate)/bias$",   ("model",)),
    (r"rglru/out/kernel$",         ("model", "data")),
    (r"rglru/out/bias$",           (None,)),
    (r"rglru/conv1d$",             (None, "model")),
    (r"rglru/gate_[ax]$",          (None, None, "model")),
    (r"rglru/bias_[ax]$",          ("model",)),
    (r"rglru/lam$",                ("model",)),
    # mamba
    (r"mamba/in_proj/kernel$",     ("data", "model")),
    (r"mamba/in_proj/bias$",       ("model",)),
    (r"mamba/conv1d$",             (None, "model")),
    (r"mamba/conv_bias$",          ("model",)),
    (r"mamba/x_proj/kernel$",      ("model", None)),
    (r"mamba/dt_proj/kernel$",     (None, "model")),
    (r"mamba/dt_proj/bias$",       ("model",)),
    (r"mamba/A_log$",              ("model", None)),
    (r"mamba/D$",                  ("model",)),
    (r"mamba/out_proj/kernel$",    ("model", "data")),
    (r"mamba/out_proj/bias$",      (None,)),
    # norms & anything small: replicate (matched last)
    (r".*",                        ()),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_path(path_str: str, shape: Sequence[int], mesh: Mesh) -> P:
    # Stacked (scan-over-layers) tensors carry a leading repeat dim. This
    # must also hold for optimizer moments, whose paths are m/blocks/...
    # and v/blocks/... — missing those replicates the whole Adam state.
    stacked = "blocks" in path_str.split("/")
    for pattern, core in _RULES:
        if re.search(pattern, path_str):
            spec: tuple[Axis, ...] = tuple(core)
            if not spec:  # replicate rule
                spec = (None,) * len(shape)
            elif stacked:
                spec = (None,) + spec
            if len(spec) != len(shape):
                # Shape/rule mismatch (e.g. missing bias dims): replicate.
                spec = (None,) * len(shape)
            return fit_spec(mesh, shape, spec)
    raise AssertionError("unreachable: catch-all rule")


def param_sharding(params_tree, mesh: Mesh):
    """Tree of NamedSharding matching an (abstract or concrete) param tree."""
    def leaf(path, x):
        return NamedSharding(mesh, spec_for_path(_path_str(path), x.shape, mesh))
    return jax.tree_util.tree_map_with_path(leaf, params_tree)


def batch_spec(mesh: Mesh, ndim: int, batch_axis: int = 0) -> P:
    """Batch inputs: leading dim over all DP axes (incl. 'pod')."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    entries: list[Axis] = [None] * ndim
    entries[batch_axis] = dp if len(dp) > 1 else dp[0]
    return P(*entries)
