"""Data pipeline: deterministic synthetic token streams + byte-level file
corpora, host-sharded for multi-host training, with background prefetch.

Every host pulls only its shard (``host_id``/``num_hosts``), matching the
per-host feeding of a pod slice; the Launchpad data nodes wrap these
iterators behind a courier service (see ``repro.core.nodes.reverb``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    batch_size: int               # per-host batch
    vocab_size: int
    seed: int = 0
    kind: str = "synthetic"       # synthetic | bytes
    path: Optional[str] = None    # for kind="bytes"


class SyntheticLM:
    """Deterministic pseudo-corpus: next token = hash of a short context.

    Gives a learnable (non-trivial, non-random) sequence distribution so
    training losses actually decrease; deterministic given (seed, host).
    """

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed * num_hosts + host_id)
        # A random linear-congruential next-token rule over a small state.
        self._a = int(self._rng.integers(1, cfg.vocab_size))
        self._b = int(self._rng.integers(0, cfg.vocab_size))

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        while True:
            start = self._rng.integers(0, cfg.vocab_size, size=(cfg.batch_size, 1))
            toks = [start]
            for _ in range(cfg.seq_len - 1):
                prev = toks[-1]
                noise = self._rng.integers(0, 4, size=prev.shape)
                nxt = (self._a * prev + self._b + noise) % cfg.vocab_size
                toks.append(nxt)
            tokens = np.concatenate(toks, axis=1).astype(np.int32)
            yield {"tokens": tokens, "labels": tokens}


class ByteCorpus:
    """Byte-level LM over a local file; documents packed into sequences."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.path, "ByteCorpus needs cfg.path"
        with open(cfg.path, "rb") as f:
            data = np.frombuffer(f.read(), np.uint8).astype(np.int32)
        # Host sharding: contiguous stripe per host.
        stripe = len(data) // num_hosts
        self._data = data[host_id * stripe:(host_id + 1) * stripe]
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed + host_id)
        if len(self._data) < cfg.seq_len + 1:
            raise ValueError("corpus shard smaller than one sequence")

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        hi = len(self._data) - cfg.seq_len - 1
        while True:
            offs = self._rng.integers(0, hi, size=cfg.batch_size)
            tokens = np.stack([self._data[o:o + cfg.seq_len] for o in offs])
            yield {"tokens": tokens, "labels": tokens}


def make_source(cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg, host_id, num_hosts)
    if cfg.kind == "bytes":
        return ByteCorpus(cfg, host_id, num_hosts)
    raise ValueError(cfg.kind)


class Prefetcher:
    """Background-thread prefetch so host data prep overlaps device compute."""

    def __init__(self, source, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, args=(iter(source),),
                                        daemon=True, name="data-prefetch")
        self._thread.start()

    def _fill(self, it):
        while not self._stop.is_set():
            try:
                item = next(it)
            except StopIteration:
                self._q.put(None)
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
