"""Reverb-lite: the replay/data service behind ReverbNode (paper §4.2).

The paper's ReverbNode exposes a Reverb (Cassirer et al., 2021) dataset —
"particularly useful in reinforcement learning settings where the dataset
can itself be filled in an online fashion". We build the substrate
ourselves: tables with bounded size, FIFO/uniform/priority sampling, and a
rate limiter enforcing a samples-per-insert ratio so learners and actors
stay in lockstep (the SPI contract is Reverb's core flow-control idea).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

import numpy as np


class WriterStalled(RuntimeError):
    """An insert blocked past its deadline because no sampler is draining
    the table (SPI budget exhausted — typically the learner died).

    Typed so actors can tell "my writer is stalled, re-resolve the replay
    service and fail over" from a real error. Raised by ``insert(...,
    raise_on_stall=True)``; the plain bool-returning path is unchanged.
    """

    def __init__(self, table: str, waited_s: float, stats: dict):
        super().__init__(
            f"insert into {table!r} stalled for {waited_s:.2f}s "
            f"(no sampler draining; table stats: {stats})")
        self.table = table
        self.waited_s = waited_s
        self.stats = stats


def is_writer_stalled(exc: BaseException) -> bool:
    """True if ``exc`` is (or wraps) a ``WriterStalled`` — cross-transport:
    inproc couriers chain the original via ``__cause__``, gRPC/shm wrap it
    in a RemoteError whose message carries the remote traceback text."""
    seen: set[int] = set()
    cur: Optional[BaseException] = exc
    while cur is not None and id(cur) not in seen:
        if isinstance(cur, WriterStalled):
            return True
        if type(cur).__name__ == "RemoteError" and "WriterStalled" in str(cur):
            return True
        seen.add(id(cur))
        cur = cur.__cause__
    return False


@dataclasses.dataclass(frozen=True)
class TableConfig:
    name: str
    max_size: int = 10_000
    sampler: str = "uniform"             # uniform | fifo | prioritized
    # Rate limiting (samples-per-insert): learner may not sample more than
    # spi * inserts, nor lag more than min_size_to_sample behind.
    min_size_to_sample: int = 1
    samples_per_insert: Optional[float] = None
    spi_tolerance: float = 2.0


class _Table:
    def __init__(self, cfg: TableConfig):
        self.cfg = cfg
        self._items: list[Any] = []
        self._priorities: list[float] = []
        self._inserts = 0
        self._samples = 0
        self._lock = threading.Lock()
        self._can_sample = threading.Condition(self._lock)
        self._can_insert = threading.Condition(self._lock)
        self._rng = np.random.default_rng(0)
        self._closed = False

    # -- rate limiter --------------------------------------------------------
    def _sample_allowed(self, n: int) -> bool:
        if len(self._items) < self.cfg.min_size_to_sample:
            return False
        spi = self.cfg.samples_per_insert
        if spi is None:
            return True
        budget = spi * self._inserts + self.cfg.spi_tolerance * spi
        return (self._samples + n) <= budget

    def _insert_allowed(self) -> bool:
        spi = self.cfg.samples_per_insert
        if spi is None:
            return True
        # Don't run unboundedly ahead of the learner.
        max_ahead = (self._samples / spi) + self.cfg.spi_tolerance
        return self._inserts <= max_ahead + self.cfg.min_size_to_sample

    # -- ops -------------------------------------------------------------------
    def insert(self, item: Any, priority: float = 1.0,
               timeout: Optional[float] = None,
               raise_on_stall: bool = False) -> bool:
        with self._lock:
            if not self._can_insert.wait_for(
                    lambda: self._insert_allowed() or self._closed, timeout):
                if raise_on_stall:
                    raise WriterStalled(
                        self.cfg.name, float(timeout or 0.0),
                        {"size": len(self._items), "inserts": self._inserts,
                         "samples": self._samples})
                return False
            if self._closed:
                return False
            self._items.append(item)
            self._priorities.append(float(priority))
            if len(self._items) > self.cfg.max_size:
                self._items.pop(0)
                self._priorities.pop(0)
            self._inserts += 1
            self._can_sample.notify_all()
            return True

    def sample(self, n: int, timeout: Optional[float] = None) -> Optional[list]:
        with self._lock:
            if not self._can_sample.wait_for(
                    lambda: self._sample_allowed(n) or self._closed, timeout):
                return None
            if self._closed and not self._items:
                return None
            size = len(self._items)
            if self.cfg.sampler == "fifo":
                take = min(n, size)
                out = self._items[:take]
                del self._items[:take], self._priorities[:take]
            elif self.cfg.sampler == "prioritized":
                pr = np.asarray(self._priorities)
                pr = pr / pr.sum()
                idx = self._rng.choice(size, size=n, p=pr)
                out = [self._items[i] for i in idx]
            else:  # uniform with replacement
                idx = self._rng.integers(0, size, size=n)
                out = [self._items[i] for i in idx]
            self._samples += n
            self._can_insert.notify_all()
            return out

    def size(self) -> int:
        with self._lock:
            return len(self._items)

    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._items), "inserts": self._inserts,
                    "samples": self._samples}

    def close(self):
        with self._lock:
            self._closed = True
            self._can_sample.notify_all()
            self._can_insert.notify_all()


class ReplayServer:
    """Multi-table replay service; the object a ReverbNode serves."""

    def __init__(self, tables: list[TableConfig]):
        self._tables = {t.name: _Table(t) for t in tables}

    def _t(self, table: str) -> _Table:
        return self._tables[table]

    def insert(self, table: str, item, priority: float = 1.0,
               timeout: Optional[float] = 10.0,
               raise_on_stall: bool = False) -> bool:
        return self._t(table).insert(item, priority, timeout,
                                     raise_on_stall=raise_on_stall)

    def sample(self, table: str, n: int,
               timeout: Optional[float] = 10.0):
        return self._t(table).sample(n, timeout)

    def size(self, table: str) -> int:
        return self._t(table).size()

    def stats(self, table: str) -> dict:
        return self._t(table).stats()

    def close(self):
        for t in self._tables.values():
            t.close()
