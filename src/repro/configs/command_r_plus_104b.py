"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus; unverified].

64L, d_model 12288, 96 heads / 8 KV heads (GQA), d_ff 33792, SwiGLU,
LayerNorm (no bias modeled as standard LN), RoPE, no QKV bias, tied
embeddings, vocab 256000. (Cohere's parallel-block residual layout is
approximated with the standard sequential pre-norm block; noted in
DESIGN.md §7.)
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    pattern=(ATTN,),
    norm="layernorm",
    norm_eps=1e-5,
    rope_theta=75e6,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=128)
