"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

26L, d_model 2560, pattern = (RG-LRU, RG-LRU, local attention) — the 1:2
local-attn : recurrent ratio (26 = 8×3 + 2 remainder). 10 heads / 1 KV
head (MQA), head_dim 256, d_ff 7680 (GeGLU), lru_width 2560, local window
2048, RMSNorm, tied + scaled embeddings, vocab 256000. Attention layers
use no RoPE beyond local positions (modeled with RoPE for simplicity).
"""

from repro.models.config import LOCAL, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=(RGLRU, RGLRU, LOCAL),
    window=2048,
    lru_width=2560,
    lru_heads=8,
    conv1d_width=4,
    mlp="geglu",
    tie_embeddings=True,
    scale_embed=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=128, window=16, lru_width=64,
        lru_heads=4)
