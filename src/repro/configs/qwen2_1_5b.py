"""Qwen2-1.5B [arXiv:2407.10671; hf].

28L, d_model 1536, 12 heads / 2 KV heads (GQA), d_ff 8960, SwiGLU,
RMSNorm, RoPE theta 1e6, QKV bias, tied embeddings, vocab 151936.
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    pattern=(ATTN,),
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128)
