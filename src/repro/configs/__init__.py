"""Registry of assigned architectures. ``get(name)`` / ``--arch <id>``."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_ARCHS = {
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-1.5b": "qwen2_1_5b",
    "command-r-plus-104b": "command_r_plus_104b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-8b": "qwen3_8b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_NAMES = tuple(_ARCHS)


def get(name: str) -> ModelConfig:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.reduced()
