"""HuBERT-XLarge backbone [arXiv:2106.07447; unverified].

Encoder-only (bidirectional) transformer, same arch as wav2vec 2.0:
48L, d_model 1280, 16 heads (MHA), d_ff 5120, GELU MLP, LayerNorm,
conv positional embedding. vocab 504 = masked-prediction codebook size.
The CNN audio frontend is a STUB — input_specs feed precomputed frame
embeddings [B, S, d_model]; decode shapes are inapplicable (no AR step).
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    pattern=(ATTN,),
    causal=False,
    rope=False,
    conv_pos=True,
    mlp="gelu",
    mlp_bias=True,
    qkv_bias=True,
    norm="layernorm",
    norm_eps=1e-5,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128, conv_pos_width=8, conv_pos_groups=4)
