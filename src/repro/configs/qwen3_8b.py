"""Qwen3-8B [hf:Qwen/Qwen3-8B; hf].

36L, d_model 4096, 32 heads / 8 KV heads (GQA), head_dim 128, d_ff 12288,
SwiGLU, RMSNorm, per-head QK-norm, RoPE theta 1e6, no bias, vocab 151936.
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    pattern=(ATTN,),
    qk_norm=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128)
