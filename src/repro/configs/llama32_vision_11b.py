"""Llama-3.2-11B-Vision backbone [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Text decoder: 40L, d_model 4096, 32 heads / 8 KV heads, d_ff 14336, SwiGLU,
RMSNorm, RoPE theta 500000, vocab 128256, with cross-attention layers to
vision embeddings interleaved every 5th layer (8 of 40). The vision tower
is a STUB — input_specs feed precomputed patch embeddings
[B, frontend_tokens, d_model].
"""

from repro.models.config import ATTN, XATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    pattern=(ATTN, ATTN, ATTN, ATTN, XATTN),
    rope_theta=500000.0,
    cross_attn_every=5,
    frontend_tokens=1601,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, frontend_tokens=16)
