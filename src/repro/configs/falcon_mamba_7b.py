"""Falcon-Mamba-7B [arXiv:2410.05355; unverified].

64 Mamba-1 blocks (attention-free), d_model 4096, d_inner 8192 (expand 2),
ssm_state 16, conv width 4, dt_rank 256, RMSNorm, vocab 65024. d_ff=0
(the Mamba block subsumes the MLP).
"""

from repro.models.config import MAMBA, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    pattern=(MAMBA,),
    rope=False,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=128, ssm_state=4,
        ssm_dt_rank=8)
