"""Mixtral-8x22B [arXiv:2401.04088; hf].

56L, d_model 6144, 48 heads / 8 KV heads (GQA), expert d_ff 16384,
8 experts top-2 (SwiGLU experts), sliding-window attention (4096),
RMSNorm, RoPE theta 1e6, vocab 32768.
"""

from repro.models.config import SWA, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    pattern=(SWA,),
    window=4096,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, window=16, num_experts=4,
        moe_capacity_factor=8.0)
