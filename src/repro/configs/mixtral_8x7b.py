"""Mixtral-8x7B [arXiv:2401.04088; hf].

32L, d_model 4096, 32 heads / 8 KV heads (GQA), expert d_ff 14336,
8 experts top-2, sliding-window attention (4096), RMSNorm, RoPE, vocab 32000.
"""

from repro.models.config import SWA, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    pattern=(SWA,),
    window=4096,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, window=16, num_experts=4,
        moe_capacity_factor=8.0)
