"""StarCoder2-3B [arXiv:2402.19173; hf].

30L, d_model 3072, 24 heads / 2 KV heads (GQA), d_ff 12288, GELU MLP
(non-gated), LayerNorm, RoPE, bias on projections, sliding window 4096,
tied embeddings, vocab 49152.
"""

from repro.models.config import SWA, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    pattern=(SWA,),
    window=4096,
    mlp="gelu",
    mlp_bias=True,
    qkv_bias=True,
    norm="layernorm",
    norm_eps=1e-5,
    rope_theta=999999.4,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=128, window=16)
