"""Service discovery and liveness for replicated nodes (the serve fabric's
control plane, but generic to any replicated service).

Launchpad wires a *static* graph: handles are resolved to endpoints at
launch and never change. A replicated service wants the dual: membership
that moves at runtime — replicas come up, die, and come back — while the
program graph stays a plain node-and-handle picture. The pieces here keep
that shape:

``Registry``
    A passive membership table served as an ordinary ``CourierNode``.
    Replicas ``register(name, endpoint, load)`` and then ``heartbeat``
    periodically, refreshing a TTL and piggybacking a fresh load report
    (free slots, queue depth, EWMA us/token — whatever the service
    measures). Consumers ``lookup()`` the live set. An entry whose beats
    stop is evicted after ``ttl_s`` (checked lazily on every read — no
    background thread to leak). ``report_failure`` lets a *caller* that
    observed a replica failing evict it immediately instead of waiting
    out the TTL; a replica that was wrongly reported re-registers on its
    next beat (``heartbeat`` returns False to tell it to), so a false
    report costs one beat period, not an outage.

``Heartbeater``
    The replica-side loop: register once, then beat every ``period_s``
    with a fresh ``load_fn()`` report, re-registering whenever the
    registry stops recognizing the name (registry restart, TTL eviction
    during a stall, failure report). Runs as a daemon thread; registry
    hiccups are absorbed (the beat that failed is simply missed).

The membership table carries a monotonic ``generation`` that bumps on
every register/evict/deregister, so a polling consumer can skip rebuilding
clients when nothing changed.

Both classes speak duck-typed registries: a ``CourierClient`` for a remote
Registry node, or the ``Registry`` object itself in-process — same calls.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from repro.core import telemetry


@dataclasses.dataclass
class ReplicaInfo:
    """One live replica, as reported by ``Registry.lookup``."""
    name: str
    endpoint: str
    load: dict
    age_s: float          # seconds since the last heartbeat


class Registry:
    """Membership + liveness table for replicated services.

    Thread-safe; all state is in-memory (the registry is itself a node —
    if it restarts, replicas re-register within one beat because their
    heartbeats come back unrecognized).
    """

    def __init__(self, ttl_s: float = 2.0):
        if ttl_s <= 0:
            raise ValueError("ttl_s must be positive")
        self._ttl = ttl_s
        self._lock = threading.Lock()
        self._entries: dict[str, dict] = {}   # name -> {endpoint, load, beat}
        self._generation = 0
        self._evictions = 0

    # -- replica side --------------------------------------------------------
    def register(self, name: str, endpoint: str,
                 load: Optional[dict] = None) -> int:
        """Add (or refresh) a replica; returns the new generation.

        Registration clears any drain mark: a replica that was evicted and
        came back is dispatchable again (the rollout controller re-derives
        and re-drains if it still wants it out of rotation)."""
        with self._lock:
            self._entries[name] = {"endpoint": endpoint,
                                   "load": dict(load or {}),
                                   "beat": time.monotonic(),
                                   "draining": False}
            self._generation += 1
            return self._generation

    def set_draining(self, name: str, draining: bool) -> bool:
        """Mark a replica undispatchable (or back in rotation) while it
        stays registered and heartbeating — the rollout controller's drain
        primitive. Routers stop picking a draining replica; its in-flight
        requests finish naturally. Returns whether the entry existed."""
        with self._lock:
            entry = self._entries.get(name)
            if entry is None:
                return False
            if entry.get("draining", False) != bool(draining):
                entry["draining"] = bool(draining)
                self._generation += 1
            return True

    def heartbeat(self, name: str, load: Optional[dict] = None) -> bool:
        """Refresh a replica's TTL (and load report). Returns False when
        the name is unknown — evicted or registry restarted — telling the
        replica to re-register."""
        now = time.monotonic()
        with self._lock:
            self._evict_expired(now)
            entry = self._entries.get(name)
            if entry is None:
                return False
            entry["beat"] = now
            if load is not None:
                entry["load"] = dict(load)
            return True

    def deregister(self, name: str) -> None:
        """Graceful removal (planned shutdown — no TTL wait)."""
        with self._lock:
            if self._entries.pop(name, None) is not None:
                self._generation += 1

    # -- consumer side -------------------------------------------------------
    def lookup(self) -> dict:
        """The live membership: ``{"generation": g, "replicas": [...]}``
        with one ``ReplicaInfo``-shaped dict per live replica."""
        now = time.monotonic()
        with self._lock:
            self._evict_expired(now)
            replicas = [{"name": name, "endpoint": e["endpoint"],
                         "load": dict(e["load"]),
                         "draining": e.get("draining", False),
                         "age_s": now - e["beat"]}
                        for name, e in sorted(self._entries.items())]
            return {"generation": self._generation, "replicas": replicas}

    def version_table(self) -> dict:
        """``{name: {endpoint, version, draining}}`` for every live
        replica — the model version each one reports serving (piggybacked
        on its heartbeat load report). This table is the rollout's single
        source of truth: a controller that restarts mid-rollout re-derives
        exactly where it was from here."""
        now = time.monotonic()
        with self._lock:
            self._evict_expired(now)
            return {name: {"endpoint": e["endpoint"],
                           "version": e["load"].get("version"),
                           "draining": e.get("draining", False)}
                    for name, e in sorted(self._entries.items())}

    def report_failure(self, name: str) -> bool:
        """A caller observed ``name`` failing: evict it now. A live replica
        re-registers on its next beat; a dead one stays gone. Returns
        whether the entry existed."""
        with self._lock:
            if self._entries.pop(name, None) is None:
                return False
            self._generation += 1
            self._evictions += 1
        telemetry.record_event("eviction", cause="caller reported a failure",
                               replica=name)
        return True

    def stats(self) -> dict:
        with self._lock:
            return {"generation": self._generation,
                    "live": len(self._entries),
                    "evictions": self._evictions,
                    "ttl_s": self._ttl}

    def telemetry(self) -> dict:
        """Standard telemetry scrape (the hub collects eviction events —
        with their causes — from here)."""
        return telemetry.telemetry_snapshot(service=self.stats())

    # -- internal ------------------------------------------------------------
    def _evict_expired(self, now: float) -> None:
        # Caller holds the lock. Lazy missed-beat eviction: an entry whose
        # last beat is older than the TTL is dead to every reader, at the
        # same instant, without a sweeper thread.
        dead = [n for n, e in self._entries.items()
                if now - e["beat"] > self._ttl]
        for name in dead:
            del self._entries[name]
            self._generation += 1
            self._evictions += 1
            telemetry.record_event(
                "eviction", cause=f"missed heartbeats for > {self._ttl}s",
                replica=name)


class Heartbeater:
    """Replica-side registration + heartbeat loop (daemon thread).

    ``registry`` is duck-typed (CourierClient or Registry). ``load_fn``
    (optional) is called once per beat and piggybacked onto it, so the
    registry's view of this replica's load is at most one period old.
    ``stop_event`` (optional) lets the owner tie the loop to a node's
    ``WorkerContext.stop_event``; ``stop()`` works either way.
    """

    def __init__(self, registry: Any, name: str, endpoint: str,
                 load_fn: Optional[Callable[[], dict]] = None,
                 period_s: float = 0.5,
                 stop_event: Optional[threading.Event] = None):
        self._registry = registry
        self._name = name
        self._endpoint = endpoint
        self._load_fn = load_fn
        self._period = period_s
        self._stop = stop_event if stop_event is not None else threading.Event()
        self._own_stop = threading.Event()      # stop() without stopping the node
        self._thread: Optional[threading.Thread] = None
        self._beats = 0
        self._misses = 0
        self._pause_until = 0.0

    def pause(self, seconds: float) -> None:
        """Fault hook: skip beats for ``seconds``. To the registry the
        node looks dead (TTL eviction); when beats resume, the next one
        comes back False and the loop re-registers — the full stall →
        evict → revive cycle, injectable on demand."""
        self._pause_until = time.monotonic() + float(seconds)

    def beat_now(self) -> None:
        """One immediate out-of-band beat (fresh ``load_fn`` report) —
        e.g. right after a weight swap, so the registry's version table
        updates without waiting out a period."""
        try:
            if not self._registry.heartbeat(self._name, self._load()):
                self._registry.register(self._name, self._endpoint,
                                        self._load())
            self._beats += 1
        except Exception:  # noqa: BLE001 - registry down: miss this beat
            self._misses += 1

    def _load(self) -> Optional[dict]:
        if self._load_fn is None:
            return None
        try:
            return self._load_fn()
        except Exception:  # noqa: BLE001 - a broken probe must not kill beats
            return None

    def _loop(self) -> None:
        while not (self._stop.is_set() or self._own_stop.is_set()):
            if time.monotonic() < self._pause_until:
                self._own_stop.wait(self._period)
                continue
            try:
                if not self._registry.heartbeat(self._name, self._load()):
                    # Evicted (TTL miss during a stall, a failure report,
                    # or a registry restart): re-introduce ourselves.
                    self._registry.register(self._name, self._endpoint,
                                            self._load())
                self._beats += 1
            except Exception:  # noqa: BLE001 - registry down: miss this beat
                self._misses += 1
            self._own_stop.wait(self._period)

    def start(self) -> "Heartbeater":
        if self._thread is None:
            try:
                self._registry.register(self._name, self._endpoint,
                                        self._load())
            except Exception:  # noqa: BLE001 - loop will register when it's up
                self._misses += 1
            self._thread = threading.Thread(
                target=self._loop, daemon=True,
                name=f"heartbeat/{self._name}")
            self._thread.start()
        return self

    def stop(self, deregister: bool = True) -> None:
        self._own_stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if deregister:
            try:
                self._registry.deregister(self._name)
            except Exception:  # noqa: BLE001 - registry gone: TTL handles it
                pass

    def stats(self) -> dict:
        return {"beats": self._beats, "misses": self._misses,
                "period_s": self._period}
