"""Fault-tolerance primitives (paper §6 made concrete).

The paper's position: no exact (lineage) recovery — the scheduler restarts
failed jobs; stateful nodes restore themselves from checkpoints; stateless
nodes restart bare. We implement the scheduler half here (restart policies
used by launchers) plus straggler mitigation for fan-out call patterns
(hedged requests), which matters at 1000-node scale where the slowest
evaluator/actor dictates step time.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent import futures as cf
from typing import Any, Callable, Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How a launcher reacts to a node's executable failing.

    max_restarts < 0 means restart forever (production default for stateless
    workers); 0 means fail fast. Exponential backoff avoids crash-looping a
    node whose dependency is still coming back.
    """
    max_restarts: int = 3
    backoff_s: float = 0.05
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 5.0

    def backoff_for(self, restart_index: int) -> float:
        return min(self.backoff_s * (self.backoff_multiplier ** restart_index),
                   self.max_backoff_s)

    def allows(self, restarts_so_far: int) -> bool:
        return self.max_restarts < 0 or restarts_so_far < self.max_restarts


NO_RESTART = RestartPolicy(max_restarts=0)
ALWAYS_RESTART = RestartPolicy(max_restarts=-1)


@dataclasses.dataclass
class NodeFailure:
    node_name: str
    error: BaseException
    restarts: int
    fatal: bool


def hedged_map(fns: Sequence[Callable[[], cf.Future]],
               hedge_after_s: Optional[float] = None,
               quorum: Optional[int] = None,
               timeout_s: Optional[float] = None) -> list[Any]:
    """Fan out async calls with straggler mitigation.

    Each entry of ``fns`` is a zero-arg callable launching one future (e.g.
    ``lambda: client.futures.evaluate(params)``). Semantics:

      * ``hedge_after_s``: if a future hasn't resolved after this delay, the
        call is *re-issued* and the first result wins (classic hedged
        request / backup request).
      * ``quorum``: return once this many results are in, cancelling the
        rest (partial fan-in — e.g. an ES evolver that only needs the
        fastest 80% of evaluators per generation).

    Returns a list aligned with ``fns``; entries that were cancelled by the
    quorum are ``None``.
    """
    n = len(fns)
    results: list[Any] = [None] * n
    done_flags = [False] * n
    done_count = 0
    target = n if quorum is None else min(quorum, n)
    lock = threading.Lock()
    all_done = threading.Event()
    primary = [fn() for fn in fns]
    hedges: list[Optional[cf.Future]] = [None] * n
    first_error: list[Optional[BaseException]] = [None]

    def _record(i: int, fut: cf.Future) -> None:
        nonlocal done_count
        with lock:
            if done_flags[i]:
                return
            try:
                results[i] = fut.result()
            except cf.CancelledError:
                return
            except BaseException as exc:  # noqa: BLE001
                if first_error[0] is None:
                    first_error[0] = exc
            done_flags[i] = True
            done_count += 1
            if done_count >= target:
                all_done.set()

    for i, fut in enumerate(primary):
        fut.add_done_callback(lambda f, i=i: _record(i, f))

    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    if hedge_after_s is not None:
        # Wait for the hedge window, then re-issue whatever is unfinished.
        if not all_done.wait(hedge_after_s):
            for i in range(n):
                with lock:
                    if done_flags[i]:
                        continue
                try:
                    hedge = fns[i]()
                except BaseException as exc:  # noqa: BLE001
                    with lock:
                        if first_error[0] is None:
                            first_error[0] = exc
                    continue
                hedges[i] = hedge
                hedge.add_done_callback(lambda f, i=i: _record(i, f))

    remaining = None if deadline is None else max(0.0, deadline - time.monotonic())
    finished = all_done.wait(remaining)
    if not finished and quorum is None and timeout_s is not None:
        raise TimeoutError(
            f"hedged_map: only {done_count}/{target} calls finished "
            f"within {timeout_s}s")

    for fut_list in (primary, hedges):
        for fut in fut_list:
            if fut is not None and not fut.done():
                fut.cancel()

    if first_error[0] is not None:
        raise first_error[0]
    return results
