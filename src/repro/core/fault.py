"""Fault-tolerance primitives (paper §6 made concrete).

The paper's position: no exact (lineage) recovery — the scheduler restarts
failed jobs; stateful nodes restore themselves from checkpoints; stateless
nodes restart bare. We implement the scheduler half here (restart policies
used by launchers) plus straggler mitigation for fan-out call patterns
(hedged requests), which matters at 1000-node scale where the slowest
evaluator/actor dictates step time.

``FaultInjector`` is the adversary: a node (or plain object) that fires a
schedule of kill / stall / transport-drop faults against named targets, so
chaos scenarios — replica dies mid-drain, node stalls past its TTL, a
transport blackholes — are written as data, reused identically by tests,
benchmarks, and example programs instead of each growing a bespoke
kill-after loop.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent import futures as cf
from typing import Any, Callable, Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How a launcher reacts to a node's executable failing.

    max_restarts < 0 means restart forever (production default for stateless
    workers); 0 means fail fast. Exponential backoff avoids crash-looping a
    node whose dependency is still coming back.
    """
    max_restarts: int = 3
    backoff_s: float = 0.05
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 5.0

    def backoff_for(self, restart_index: int) -> float:
        return min(self.backoff_s * (self.backoff_multiplier ** restart_index),
                   self.max_backoff_s)

    def allows(self, restarts_so_far: int) -> bool:
        return self.max_restarts < 0 or restarts_so_far < self.max_restarts


NO_RESTART = RestartPolicy(max_restarts=0)
ALWAYS_RESTART = RestartPolicy(max_restarts=-1)


@dataclasses.dataclass
class NodeFailure:
    node_name: str
    error: BaseException
    restarts: int
    fatal: bool


def hedged_map(fns: Sequence[Callable[[], cf.Future]],
               hedge_after_s: Optional[float] = None,
               quorum: Optional[int] = None,
               timeout_s: Optional[float] = None,
               return_exceptions: bool = False) -> list[Any]:
    """Fan out async calls with straggler mitigation.

    Each entry of ``fns`` is a zero-arg callable launching one future (e.g.
    ``lambda: client.futures.evaluate(params)``). Semantics:

      * ``hedge_after_s``: if a future hasn't resolved after this delay, the
        call is *re-issued* and the first result wins (classic hedged
        request / backup request).
      * ``quorum``: return once this many results are in, cancelling the
        rest (partial fan-in — e.g. an ES evolver that only needs the
        fastest 80% of evaluators per generation).
      * ``return_exceptions``: per-call failures become entries in the
        result list instead of raising — graceful degradation for quorum
        aggregation over a fleet where some members may be mid-restart
        (the caller inspects ``isinstance(r, BaseException)``).

    Returns a list aligned with ``fns``; entries that were cancelled by the
    quorum are ``None``.
    """
    n = len(fns)
    results: list[Any] = [None] * n
    done_flags = [False] * n
    done_count = 0
    target = n if quorum is None else min(quorum, n)
    lock = threading.Lock()
    all_done = threading.Event()
    primary = [fn() for fn in fns]
    hedges: list[Optional[cf.Future]] = [None] * n
    first_error: list[Optional[BaseException]] = [None]

    def _record(i: int, fut: cf.Future) -> None:
        nonlocal done_count
        with lock:
            if done_flags[i]:
                return
            try:
                results[i] = fut.result()
            except cf.CancelledError:
                return
            except BaseException as exc:  # noqa: BLE001
                if return_exceptions:
                    results[i] = exc
                elif first_error[0] is None:
                    first_error[0] = exc
            done_flags[i] = True
            done_count += 1
            if done_count >= target:
                all_done.set()

    for i, fut in enumerate(primary):
        fut.add_done_callback(lambda f, i=i: _record(i, f))

    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    if hedge_after_s is not None:
        # Wait for the hedge window, then re-issue whatever is unfinished.
        if not all_done.wait(hedge_after_s):
            for i in range(n):
                with lock:
                    if done_flags[i]:
                        continue
                try:
                    hedge = fns[i]()
                except BaseException as exc:  # noqa: BLE001
                    with lock:
                        if not return_exceptions and first_error[0] is None:
                            first_error[0] = exc
                    continue  # primary is still pending; let it decide
                hedges[i] = hedge
                hedge.add_done_callback(lambda f, i=i: _record(i, f))

    remaining = None if deadline is None else max(0.0, deadline - time.monotonic())
    finished = all_done.wait(remaining)
    if not finished and quorum is None and timeout_s is not None:
        raise TimeoutError(
            f"hedged_map: only {done_count}/{target} calls finished "
            f"within {timeout_s}s")

    for fut_list in (primary, hedges):
        for fut in fut_list:
            if fut is not None and not fut.done():
                fut.cancel()

    if first_error[0] is not None:
        raise first_error[0]
    return results


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault.

    ``kind`` is duck-typed: it names a method on the target (``kill``,
    ``stall``, ``drop``, ...); stall/drop take ``duration_s``. ``target``
    indexes into the injector's targets list (events stay handle-free so
    they serialize cleanly into a program graph). Exactly one trigger
    should be set:

      * ``after_served`` — fires once the progress sources report this
        many completed requests (count-based, schedule-independent);
      * ``after_s``      — fires this many seconds after the injector
        starts (time-based);
      * ``when``         — fires when this zero-arg predicate first
        returns True (e.g. "a replica is draining in the registry").
        In-process use only — predicates don't serialize.
    """
    kind: str
    target: int = 0
    after_served: Optional[int] = None
    after_s: Optional[float] = None
    when: Optional[Callable[[], bool]] = None
    duration_s: float = 0.0


class FaultInjector:
    """Fires a schedule of faults against named targets.

    Runs as a ``PyNode`` (``run()`` polls until every event has fired or
    the program stops) or driven manually via ``poll()`` from a test.
    ``targets`` are handles/clients/objects exposing the fault methods;
    ``progress`` sources expose ``stats()`` with a ``completed`` counter
    (routers do) and power the ``after_served`` trigger.

    A fault firing is best-effort by design: the target may already be
    dead (that is the point of chaos testing), so per-event errors are
    recorded on the event outcome, never raised.
    """

    def __init__(self, events: Sequence[FaultEvent], targets: Sequence[Any],
                 progress: Sequence[Any] = (), poll_s: float = 0.002):
        self._events = list(events)
        self._targets = list(targets)
        self._progress = list(progress)
        self._poll_s = poll_s
        self._t0: Optional[float] = None
        self.fired: list[dict] = []     # {kind, target, t_s, error}
        self._pending = list(range(len(self._events)))

    def _served(self) -> int:
        total = 0
        for src in self._progress:
            try:
                total += int(src.stats().get("completed", 0))
            except Exception:  # noqa: BLE001 - progress source mid-restart
                pass
        return total

    def _due(self, e: FaultEvent, now: float, served: Optional[int]) -> bool:
        if e.after_served is not None:
            return served is not None and served >= e.after_served
        if e.after_s is not None:
            return now - self._t0 >= e.after_s
        if e.when is not None:
            try:
                return bool(e.when())
            except Exception:  # noqa: BLE001
                return False
        return True  # no trigger: fire on first poll

    def _fire(self, e: FaultEvent) -> None:
        err = None
        try:
            target = self._targets[e.target]
            method = getattr(target, e.kind)
            if e.kind in ("stall", "drop"):
                method(e.duration_s)
            else:
                method()
        except Exception as exc:  # noqa: BLE001 - target may already be dead
            err = repr(exc)
        self.fired.append({"kind": e.kind, "target": e.target,
                           "t_s": time.monotonic() - self._t0, "error": err})
        state = f"failed ({err})" if err else "fired"
        print(f"fault: {e.kind} -> target {e.target} {state}; "
              "traffic continues", flush=True)

    def poll(self) -> int:
        """Fire every due pending event; returns how many remain."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        now = time.monotonic()
        needs_count = any(self._events[i].after_served is not None
                          for i in self._pending)
        served = self._served() if needs_count else None
        still = []
        for i in self._pending:
            if self._due(self._events[i], now, served):
                self._fire(self._events[i])
            else:
                still.append(i)
        self._pending = still
        return len(self._pending)

    def run(self) -> None:
        from repro.core.nodes.base import get_current_context
        ctx = get_current_context()
        self._t0 = time.monotonic()
        while self._pending and not ctx.should_stop:
            if self.poll() == 0:
                return
            ctx.wait_for_stop(self._poll_s)
