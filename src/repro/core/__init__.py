"""repro.core — the Launchpad programming model (the paper's contribution).

Public API mirrors the paper:

    from repro import core as lp

    p = lp.Program('ps')
    with p.group('server'):
        server = p.add_node(lp.CourierNode(ParamServer))
    with p.group('requester'):
        for _ in range(n):
            p.add_node(lp.CourierNode(Requester, server))
    lp.ThreadLauncher().launch(p, resources={...})
"""

from repro.core import courier, telemetry
from repro.core.addressing import Address, AddressTable
from repro.core.discovery import Heartbeater, Registry, ReplicaInfo
from repro.core.fault import (ALWAYS_RESTART, NO_RESTART, FaultEvent,
                              FaultInjector, NodeFailure, RestartPolicy,
                              hedged_map)
from repro.core.handles import Handle, collect_handles, map_handles
from repro.core.launchers import (DryRunLauncher, Launcher, ProcessLauncher,
                                  ProgramTestError, ThreadLauncher,
                                  launch_and_wait)
from repro.core.nodes import (Cacher, CacherNode, ColocationNode, CourierHandle,
                              CourierNode, Executable, MeshWorkerNode, Node,
                              PyNode, ReverbNode, WorkerContext,
                              get_current_context, stop_program)
from repro.core.program import Program
from repro.core.resources import DEFAULT_GROUP, ResourceGroup
from repro.core.telemetry import TelemetryHub, get_logger

__all__ = [
    "Program", "ResourceGroup", "DEFAULT_GROUP",
    "Node", "Executable", "Handle", "Address", "AddressTable",
    "PyNode", "CourierNode", "CourierHandle", "CacherNode", "Cacher",
    "ColocationNode", "MeshWorkerNode", "ReverbNode",
    "WorkerContext", "get_current_context", "stop_program",
    "collect_handles", "map_handles",
    "Launcher", "ThreadLauncher", "ProcessLauncher", "DryRunLauncher",
    "launch_and_wait", "ProgramTestError",
    "RestartPolicy", "NodeFailure", "NO_RESTART", "ALWAYS_RESTART", "hedged_map",
    "FaultEvent", "FaultInjector",
    "Registry", "Heartbeater", "ReplicaInfo",
    "TelemetryHub", "get_logger", "telemetry",
    "courier",
]
