"""Fabric-wide telemetry: metrics, cross-node request tracing, collection.

Launchpad's pitch is that a distributed program should be as easy to
*understand* as it is to define. This module is the understanding half:
one dependency-light layer that every node shares, with three pillars.

**Metrics** — a per-process :class:`MetricsRegistry` of counters, gauges
and mergeable log2-bucket histograms. The record path is lock-free:
counters/gauges are single attribute writes (GIL-atomic), histograms are
one ``math.frexp`` plus one preallocated-``int64``-array increment —
cheap enough to live inside the decode loop. Every node exposes the
registry through a ``telemetry()`` RPC alongside its existing ``load()``.

**Tracing** — a per-request :class:`TraceContext` (trace id + span
parent) carried in a ``contextvars`` var and injected into the courier
call envelope as a reserved ``__trace__`` kwarg. Injection happens at the
one client chokepoint (:class:`~repro.core.courier.client.CourierClient`)
and extraction at the two invocation chokepoints (``CourierServer._invoke``
and ``InProcTransport``), so propagation is transport-agnostic by
construction: inproc, shm and gRPC all carry it because it rides the
serialized kwargs. A sampled request yields :func:`span` records for the
full critical path — router queue/dispatch, engine admission wait,
prefill, each fused decode window, reply — landing in a per-process ring
buffer (:class:`SpanBuffer`) that the collector drains.

**Collection** — a :class:`TelemetryHub` node discovers scrape targets
through the ``Registry`` (plus explicit handles for unregistered nodes
like routers), merges metric snapshots **per pid** (thread-launched
fabrics share one process registry — deduping by pid keeps a node's
counters from being summed N times), accumulates drained spans and fabric
events (evictions, drains, swaps, respawns, Overloaded rejections — each
with a cause), and writes a JSON snapshot plus a Chrome trace-event
timeline (:func:`chrome_trace`) loadable in Perfetto.

Timestamps: span ``ts`` is wall-clock ``time.time()`` so spans recorded
in different same-host processes align on one timeline; durations come
from ``perf_counter`` deltas. Cross-host alignment is out of scope (the
shm fabric is same-host anyway).

Overhead budget: an unsampled request pays one contextvar read per
courier hop (~100ns) and nothing else; a sampled request pays ~2us per
span (dict build + deque append). The serve benchmark gates the
telemetry-on arm at <= 1.03x the off arm at the mixed scenario.
"""

from __future__ import annotations

import collections
import contextlib
import contextvars
import dataclasses
import itertools
import json
import math
import os
import sys
import threading
import time
import traceback
from typing import Any, Callable, Iterable, Optional

import numpy as np

# ---- metrics -----------------------------------------------------------------

# Histogram geometry: log2 buckets with 8 sub-buckets per octave (relative
# error <= ~4.5% at the bucket midpoint). frexp exponents [EMIN, EMIN+NEXP)
# cover ~1e-8 .. ~5e10 — microseconds from 10ns to 14 hours.
_SUB = 8
_EMIN = -26
_NEXP = 64
_NBUCKETS = _NEXP * _SUB


class Counter:
    """Monotonic counter. ``inc`` is a single in-place add — no lock (the
    GIL serializes the read-modify-write at the bytecode level closely
    enough for telemetry; we trade perfect atomicity for zero hot-path
    cost)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Mergeable log2-bucket histogram with sub-bucket resolution.

    ``record`` appends to a preallocated int64 array — one ``frexp``, one
    element increment, no locks, no allocation. ``count``/``sum`` are
    exact (so ``mean`` is exact); percentiles are bucket-midpoint
    approximations clamped to the observed [min, max]. Two histograms
    merge by adding their bucket arrays — the collector's roll-up is
    exactly as accurate as any single node's histogram.
    """

    __slots__ = ("name", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, name: str):
        self.name = name
        self.counts = np.zeros(_NBUCKETS, np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= 0.0:
            idx = 0
        else:
            m, e = math.frexp(v)            # v = m * 2**e, m in [0.5, 1)
            idx = ((e - _EMIN) << 3) + int((m - 0.5) * 16.0)
            if idx < 0:
                idx = 0
            elif idx >= _NBUCKETS:
                idx = _NBUCKETS - 1
        self.counts[idx] += 1

    @staticmethod
    def _bucket_mid(idx: int) -> float:
        e = (idx >> 3) + _EMIN
        sub = idx & 7
        lo = math.ldexp(1.0, e - 1) * (1.0 + sub / 8.0)
        return lo * (1.0 + 1.0 / 16.0)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100])."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(self.count * q / 100.0))
        acc = 0
        for idx in np.nonzero(self.counts)[0]:
            acc += int(self.counts[idx])
            if acc >= rank:
                return min(max(self._bucket_mid(int(idx)), self.vmin),
                           self.vmax)
        return self.vmax

    def reset(self) -> None:
        """Zero in place (owners that scope a window — e.g. a Meter
        claiming a possibly-stale registry histogram — start fresh
        without replacing the object other readers already hold)."""
        self.counts[:] = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def merge(self, other: "Histogram") -> "Histogram":
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def snapshot(self) -> dict:
        nz = np.nonzero(self.counts)[0]
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "buckets": {int(i): int(self.counts[i]) for i in nz}}

    @classmethod
    def from_snapshot(cls, name: str, snap: dict) -> "Histogram":
        h = cls(name)
        h.count = int(snap["count"])
        h.total = float(snap["sum"])
        if h.count:
            h.vmin, h.vmax = float(snap["min"]), float(snap["max"])
        for idx, n in snap["buckets"].items():
            h.counts[int(idx)] = int(n)
        return h


def merge_histogram_snapshots(snaps: Iterable[dict],
                              name: str = "merged") -> Histogram:
    out = Histogram(name)
    for snap in snaps:
        out.merge(Histogram.from_snapshot(name, snap))
    return out


class MetricsRegistry:
    """Per-process get-or-create registry of named metrics.

    Creation takes a lock (cold path); recording against a held metric
    object is lock-free. ``snapshot()`` is the ``telemetry()`` RPC's
    metrics payload — JSON-friendly, mergeable downstream.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(name))
        return h

    def snapshot(self) -> dict:
        with self._lock:
            counters = {n: c.value for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            hists = {n: h.snapshot() for n, h in self._histograms.items()}
        return {"counters": counters, "gauges": gauges, "histograms": hists}

    def reset(self) -> None:
        """Zero everything (benchmarks: exclude warmup from the window)."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0
            for g in self._gauges.values():
                g.value = 0.0
            for h in self._histograms.values():
                h.counts[:] = 0
                h.count, h.total = 0, 0.0
                h.vmin, h.vmax = math.inf, -math.inf


_metrics = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-wide metrics registry every node records into."""
    return _metrics


def merge_metric_snapshots(snaps: Iterable[dict]) -> dict:
    """Fabric roll-up: counters sum, gauges last-write-wins, histograms
    merge by bucket. Input dicts are ``MetricsRegistry.snapshot()``s."""
    counters: dict[str, int] = {}
    gauges: dict[str, float] = {}
    hists: dict[str, Histogram] = {}
    for snap in snaps:
        for n, v in snap.get("counters", {}).items():
            counters[n] = counters.get(n, 0) + v
        gauges.update(snap.get("gauges", {}))
        for n, h in snap.get("histograms", {}).items():
            if n in hists:
                hists[n].merge(Histogram.from_snapshot(n, h))
            else:
                hists[n] = Histogram.from_snapshot(n, h)
    out_h = {}
    for n, h in hists.items():
        out_h[n] = h.snapshot()
        out_h[n]["p50"] = h.percentile(50)
        out_h[n]["p95"] = h.percentile(95)
        out_h[n]["p99"] = h.percentile(99)
        out_h[n]["mean"] = h.mean
    return {"counters": counters, "gauges": gauges, "histograms": out_h}


# ---- tracing -----------------------------------------------------------------

TRACE_KEY = "__trace__"

_ids = itertools.count(1)
_id_lock = threading.Lock()


def _new_id() -> str:
    with _id_lock:
        n = next(_ids)
    return f"{os.getpid():x}.{n:x}"


@dataclasses.dataclass
class TraceContext:
    """One request's position in its trace: which trace, and which span
    is the parent of anything recorded under this context."""

    trace_id: str
    parent_id: Optional[str] = None
    sampled: bool = True

    def to_wire(self) -> tuple:
        return (self.trace_id, self.parent_id, self.sampled)

    @classmethod
    def from_wire(cls, wire: Any) -> Optional["TraceContext"]:
        try:
            trace_id, parent_id, sampled = wire
            return cls(str(trace_id),
                       None if parent_id is None else str(parent_id),
                       bool(sampled))
        except Exception:  # noqa: BLE001 - malformed envelope: drop trace
            return None

    def child(self, span_id: str) -> "TraceContext":
        return TraceContext(self.trace_id, span_id, self.sampled)


_ctx_var: contextvars.ContextVar[Optional[TraceContext]] = \
    contextvars.ContextVar("repro_trace_ctx", default=None)


def current_context() -> Optional[TraceContext]:
    return _ctx_var.get()


def new_span_id() -> str:
    """Mint a span id up front — for callers that must inject a child
    context into an envelope *before* the span itself is recorded (the
    router pre-parents engine-side spans under its dispatch span)."""
    return _new_id()


def start_trace(sampled: bool = True) -> TraceContext:
    """Mint a fresh trace root context (client submit side). Activate it
    with :func:`activate` (or pass it explicitly to :func:`span`)."""
    return TraceContext(trace_id=_new_id(), parent_id=None, sampled=sampled)


@contextlib.contextmanager
def activate(ctx: Optional[TraceContext]):
    """Install ``ctx`` as the current trace context for the block —
    the server-side half of envelope propagation."""
    token = _ctx_var.set(ctx)
    try:
        yield ctx
    finally:
        _ctx_var.reset(token)


def inject(kwargs: dict) -> dict:
    """Client chokepoint: fold the current sampled trace context into a
    call's kwargs under the reserved ``__trace__`` key. Returns the input
    dict unchanged when there is nothing to propagate."""
    ctx = _ctx_var.get()
    if ctx is None or not ctx.sampled or TRACE_KEY in kwargs:
        return kwargs
    out = dict(kwargs)
    out[TRACE_KEY] = ctx.to_wire()
    return out


def extract(kwargs: dict) -> Optional[TraceContext]:
    """Server chokepoint: pop and decode the trace envelope (mutates
    ``kwargs`` so the service method never sees the reserved key)."""
    wire = kwargs.pop(TRACE_KEY, None)
    if wire is None:
        return None
    return TraceContext.from_wire(wire)


class SpanBuffer:
    """Bounded per-process ring of finished spans. ``append`` rides
    deque's atomic append (no lock); ``drain`` empties via atomic
    poplefts, so a concurrent recorder never blocks on a scrape."""

    def __init__(self, maxlen: int = 8192):
        self._dq: collections.deque = collections.deque(maxlen=maxlen)

    def append(self, item: dict) -> None:
        self._dq.append(item)

    def drain(self) -> list[dict]:
        out = []
        while True:
            try:
                out.append(self._dq.popleft())
            except IndexError:
                return out

    def peek(self) -> list[dict]:
        return list(self._dq)

    def __len__(self) -> int:
        return len(self._dq)


_spans = SpanBuffer()
_events = SpanBuffer(maxlen=2048)

# Fallback node attribution for spans recorded on threads that never got a
# WorkerContext (engine decode loops, dispatcher threads). Services capture
# telemetry.node_name() at construction and pass it explicitly when they
# can; this keeps the default better than "standalone".
_default_node: Optional[str] = None


def set_default_node(name: str) -> None:
    global _default_node
    _default_node = name


def node_name() -> str:
    """Best-effort name of the node this thread serves."""
    from repro.core.nodes.base import _context_local
    ctx = getattr(_context_local, "ctx", None)
    if ctx is not None and ctx.node_name != "standalone":
        return ctx.node_name
    return _default_node or f"pid-{os.getpid()}"


def record_span(name: str, ctx: TraceContext, start_wall: float,
                dur_s: float, node: Optional[str] = None,
                span_id: Optional[str] = None, **attrs) -> str:
    """Append one finished span (explicit-timestamps API, used by the
    engine thread which reconstructs spans after the fact). Returns the
    span id so callers can parent further spans under it."""
    sid = span_id or _new_id()
    _spans.append({"name": name, "trace": ctx.trace_id, "id": sid,
                   "parent": ctx.parent_id, "node": node or node_name(),
                   "ts": start_wall, "dur": dur_s, "attrs": attrs})
    return sid


@contextlib.contextmanager
def span(name: str, ctx: Optional[TraceContext] = None, **attrs):
    """Timed span context manager. No-op (one contextvar read) when the
    request is unsampled. Within the block the current context points at
    this span, so nested spans — including remote ones, via the envelope —
    parent correctly."""
    c = ctx if ctx is not None else _ctx_var.get()
    if c is None or not c.sampled:
        yield None
        return
    sid = _new_id()
    token = _ctx_var.set(c.child(sid))
    t0w = time.time()
    t0 = time.perf_counter()
    mutable_attrs = dict(attrs)
    try:
        yield mutable_attrs
    finally:
        _ctx_var.reset(token)
        record_span(name, c, t0w, time.perf_counter() - t0,
                    span_id=sid, **mutable_attrs)


def record_event(kind: str, cause: str = "", node: Optional[str] = None,
                 **attrs) -> None:
    """One fabric event — an eviction, drain, swap, respawn, Overloaded
    rejection — with its cause. Collected by the hub alongside spans."""
    _events.append({"kind": kind, "cause": cause,
                    "node": node or node_name(), "ts": time.time(),
                    "attrs": attrs})


def spans_buffer() -> SpanBuffer:
    return _spans


def events_buffer() -> SpanBuffer:
    return _events


def telemetry_snapshot(drain: bool = True, service: Optional[dict] = None,
                       **extra) -> dict:
    """The standard ``telemetry()`` RPC payload: process metrics plus the
    drained span/event rings, stamped with the pid so a collector scraping
    N thread-launched nodes in one process merges the shared registry
    once, not N times."""
    snap = {"node": node_name(), "pid": os.getpid(), "time": time.time(),
            "metrics": _metrics.snapshot(),
            "spans": _spans.drain() if drain else _spans.peek(),
            "events": _events.drain() if drain else _events.peek()}
    if service is not None:
        snap["service"] = service
    snap.update(extra)
    return snap


# ---- structured per-node logging --------------------------------------------

_log_lock = threading.Lock()


class NodeLogger:
    """Launchpad-style per-node logger: every line is prefixed with the
    node's name so interleaved output from N workers stays attributable.
    ``exception`` appends the current traceback and records a fabric
    event, so a supervisor respawn has a queryable cause, not just a
    scrolled-away stack."""

    __slots__ = ("node",)

    def __init__(self, node: str):
        self.node = node

    def _emit(self, level: str, msg: str, tb: Optional[str] = None) -> None:
        ts = time.strftime("%H:%M:%S", time.localtime())
        line = f"{ts} [{self.node}] {level}: {msg}"
        if tb:
            line = f"{line}\n{tb.rstrip()}"
        with _log_lock:
            print(line, file=sys.stderr, flush=True)

    def info(self, msg: str, **kv) -> None:
        self._emit("INFO", _fmt(msg, kv))

    def warning(self, msg: str, **kv) -> None:
        self._emit("WARN", _fmt(msg, kv))

    def error(self, msg: str, **kv) -> None:
        self._emit("ERROR", _fmt(msg, kv))
        record_event("error", cause=msg, node=self.node, **kv)

    def exception(self, msg: str, **kv) -> None:
        self._emit("ERROR", _fmt(msg, kv), tb=traceback.format_exc())
        record_event("error", cause=msg, node=self.node, **kv)


def _fmt(msg: str, kv: dict) -> str:
    if not kv:
        return msg
    tail = " ".join(f"{k}={v}" for k, v in kv.items())
    return f"{msg} ({tail})"


def get_logger(node: Optional[str] = None) -> NodeLogger:
    return NodeLogger(node or node_name())


# ---- Chrome trace-event (Perfetto) export ------------------------------------

def chrome_trace(spans: Iterable[dict],
                 events: Iterable[dict] = ()) -> dict:
    """Render spans as a Chrome trace-event JSON object (the ``{"traceEvents":
    [...]}`` form Perfetto and chrome://tracing load directly). Nodes map
    to pids (with ``process_name`` metadata), traces map to tids so one
    request's spans share a row; fabric events become instants."""
    pids: dict[str, int] = {}
    tids: dict[str, int] = {}

    def pid_of(node: str) -> int:
        if node not in pids:
            pids[node] = len(pids) + 1
        return pids[node]

    def tid_of(trace: str) -> int:
        if trace not in tids:
            tids[trace] = len(tids) + 1
        return tids[trace]

    out = []
    for s in spans:
        out.append({"ph": "X", "name": s["name"], "cat": "span",
                    "ts": s["ts"] * 1e6, "dur": max(s["dur"], 1e-7) * 1e6,
                    "pid": pid_of(s["node"]), "tid": tid_of(s["trace"]),
                    "args": {"trace": s["trace"], "id": s["id"],
                             "parent": s["parent"], **s.get("attrs", {})}})
    for e in events:
        out.append({"ph": "i", "name": f"{e['kind']}: {e['cause']}"
                    if e.get("cause") else e["kind"],
                    "cat": "event", "s": "g", "ts": e["ts"] * 1e6,
                    "pid": pid_of(e["node"]), "tid": 0,
                    "args": dict(e.get("attrs", {}))})
    meta = [{"ph": "M", "name": "process_name", "pid": pid,
             "args": {"name": node}} for node, pid in pids.items()]
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def trace_coverage(spans: Iterable[dict], trace_id: str,
                   start_wall: float, dur_s: float) -> float:
    """Fraction of the [start, start+dur] window covered by the union of
    the trace's span intervals — the "does the trace explain every
    microsecond" number the bench gates at >= 0.95. The root span itself
    (covering the whole window by definition) is excluded."""
    if dur_s <= 0:
        return 0.0
    end_wall = start_wall + dur_s
    ivals = []
    for s in spans:
        if s["trace"] != trace_id or s.get("attrs", {}).get("root"):
            continue
        a = max(s["ts"], start_wall)
        b = min(s["ts"] + s["dur"], end_wall)
        if b > a:
            ivals.append((a, b))
    ivals.sort()
    covered, cur_a, cur_b = 0.0, None, None
    for a, b in ivals:
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                covered += cur_b - cur_a
            cur_a, cur_b = a, b
        else:
            cur_b = max(cur_b, b)
    if cur_b is not None:
        covered += cur_b - cur_a
    return covered / dur_s


# ---- the collector -----------------------------------------------------------

class TelemetryHub:
    """Fabric telemetry collector, run as an ordinary program node.

    Scrape targets come from two places: the ``Registry`` (every replica
    that registers and heartbeats — engines, train workers) and explicit
    ``targets`` handles for nodes that serve couriers but do not register
    (routers, the registry itself). Each scrape calls the target's
    ``telemetry()`` RPC: metric snapshots replace the previous snapshot
    *per pid* (counters are cumulative, and thread-launched fabrics share
    one process registry — last-per-pid is the merge that never double
    counts), while spans and events accumulate.

    ``out_dir`` (optional): on every scrape — and on shutdown — the hub
    writes ``telemetry.json`` (merged snapshot) and ``trace.json``
    (Chrome trace-event timeline, Perfetto-loadable).
    """

    def __init__(self, registry: Any = None, targets: Iterable[Any] = (),
                 poll_s: float = 0.5, out_dir: Optional[str] = None,
                 client_factory: Optional[Callable[[str], Any]] = None):
        from repro.core import courier
        self._registry = registry
        self._targets = list(targets)
        self._poll_s = poll_s
        self._out_dir = out_dir
        self._client_factory = client_factory or courier.client_for
        self._lock = threading.Lock()
        self._clients: dict[str, Any] = {}
        self._by_pid: dict[int, dict] = {}        # pid -> latest metrics
        self._service: dict[str, dict] = {}       # node -> service extras
        self._spans: list[dict] = []
        self._events: list[dict] = []
        self._scrapes = 0
        self._scrape_errors = 0

    # -- scraping ------------------------------------------------------------
    def _registry_clients(self) -> list[tuple[str, Any]]:
        if self._registry is None:
            return []
        try:
            view = self._registry.lookup()
        except Exception:  # noqa: BLE001 - registry down: scrape targets only
            return []
        out = []
        for rep in view["replicas"]:
            ep = rep["endpoint"]
            cli = self._clients.get(ep)
            if cli is None:
                try:
                    cli = self._client_factory(ep)
                except Exception:  # noqa: BLE001 - endpoint unreachable
                    continue
                self._clients[ep] = cli
            out.append((rep["name"], cli))
        return out

    def scrape_once(self) -> int:
        """One collection pass over every reachable target; returns how
        many targets answered."""
        ok = 0
        seen_pids: set[int] = set()
        # Service stats are keyed by a name the HUB derives (replica name
        # from the registry, endpoint for explicit targets): the reply's
        # self-reported node name is whatever thread served the RPC —
        # for in-process couriers that's the hub's own thread, and every
        # target would collapse onto one key.
        pairs = [(getattr(t, "endpoint", None), t) for t in self._targets]
        pairs += self._registry_clients()
        for i, (name, target) in enumerate(pairs):
            try:
                snap = target.telemetry()
            except Exception:  # noqa: BLE001 - dead target: next pass
                with self._lock:
                    self._scrape_errors += 1
                continue
            ok += 1
            pid = int(snap.get("pid", 0))
            with self._lock:
                self._scrapes += 1
                # Same-process targets share one registry snapshot; merge
                # it once per scrape pass. Spans/events were *drained* by
                # whichever sibling's RPC ran first, so accumulation is
                # already dedup'd by construction.
                if pid not in seen_pids:
                    self._by_pid[pid] = snap.get("metrics", {})
                    seen_pids.add(pid)
                self._spans.extend(snap.get("spans", []))
                self._events.extend(snap.get("events", []))
                if "service" in snap:
                    key = name or str(snap.get("node", f"target-{i}"))
                    self._service[key] = snap["service"]
        if self._out_dir:
            self.write(self._out_dir)
        return ok

    # -- views ---------------------------------------------------------------
    def merged_metrics(self) -> dict:
        with self._lock:
            return merge_metric_snapshots(list(self._by_pid.values()))

    def spans(self) -> list[dict]:
        with self._lock:
            return list(self._spans)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def snapshot(self) -> dict:
        """RPC-friendly merged view of everything collected so far."""
        with self._lock:
            spans = list(self._spans)
            events = list(self._events)
            service = {k: dict(v) for k, v in self._service.items()}
            per_pid = list(self._by_pid.values())
            stats = {"scrapes": self._scrapes,
                     "scrape_errors": self._scrape_errors,
                     "processes": len(self._by_pid)}
        return {"merged": merge_metric_snapshots(per_pid),
                "services": service, "spans": spans, "events": events,
                "hub": stats}

    def coverage(self, trace_id: str, start_wall: float,
                 dur_s: float) -> float:
        return trace_coverage(self.spans(), trace_id, start_wall, dur_s)

    # -- export --------------------------------------------------------------
    def write(self, out_dir: str) -> dict[str, str]:
        os.makedirs(out_dir, exist_ok=True)
        snap = self.snapshot()
        spans = snap.pop("spans")
        events = snap["events"]
        snap["span_count"] = len(spans)
        paths = {"snapshot": os.path.join(out_dir, "telemetry.json"),
                 "trace": os.path.join(out_dir, "trace.json")}
        with open(paths["snapshot"], "w") as f:
            json.dump(snap, f, indent=2, default=str)
            f.write("\n")
        with open(paths["trace"], "w") as f:
            json.dump(chrome_trace(spans, events), f)
            f.write("\n")
        return paths

    # -- node protocol -------------------------------------------------------
    def run(self) -> None:
        """Program-node loop: scrape every ``poll_s`` until the program
        stops, then one final scrape + export so shutdown never loses the
        tail of the story."""
        from repro.core.nodes.base import get_current_context
        ctx = get_current_context()
        while not ctx.wait_for_stop(self._poll_s):
            self.scrape_once()
        self.scrape_once()
        self.close()

    def close(self) -> None:
        if self._out_dir:
            with contextlib.suppress(Exception):
                self.write(self._out_dir)
        for cli in self._clients.values():
            with contextlib.suppress(Exception):
                cli.close()
        self._clients.clear()
