"""Node and Executable protocols (paper §2, §4).

A *node* is a datastructure describing computation that **will** run — a
factory for the service. A node may materialize into one or more
*executables* (a service can be several processes). Decoupling declaration
from implementation lets the same program run under different launchers.
"""

from __future__ import annotations

import abc
import threading
from typing import Any, Callable, Optional, Sequence

from repro.core.addressing import Address
from repro.core.handles import Handle


class WorkerContext:
    """Execution-phase context handed to every executable.

    Gives services cooperative shutdown (``should_stop`` /
    ``wait_for_stop``) and the ability to terminate the whole program
    (``stop_program`` — like ``lp.stop()``).
    """

    def __init__(self,
                 node_name: str = "worker",
                 stop_event: Optional[threading.Event] = None,
                 stop_program_fn: Optional[Callable[[], None]] = None):
        self.node_name = node_name
        self.stop_event = stop_event if stop_event is not None else threading.Event()
        self._stop_program_fn = stop_program_fn
        # The node's own resolved serving endpoint (set by courier-serving
        # executables before the service object is constructed). This is
        # how a service can *advertise itself* — e.g. register with a
        # discovery Registry — without the program author threading the
        # address through every constructor. None for non-courier nodes.
        self.endpoint: Optional[str] = None

    @property
    def should_stop(self) -> bool:
        return self.stop_event.is_set()

    def wait_for_stop(self, timeout: Optional[float] = None) -> bool:
        return self.stop_event.wait(timeout)

    def stop_program(self) -> None:
        """Request termination of the entire distributed program."""
        self.stop_event.set()
        if self._stop_program_fn is not None:
            self._stop_program_fn()


# Thread-local so library code (e.g. a service method) can reach its context
# without threading it through every call.
_context_local = threading.local()


def set_current_context(ctx: WorkerContext) -> None:
    _context_local.ctx = ctx


def get_current_context() -> WorkerContext:
    ctx = getattr(_context_local, "ctx", None)
    if ctx is None:
        # Outside any launcher (e.g. unit tests poking a service directly):
        # hand back a standalone context rather than failing.
        ctx = WorkerContext(node_name="standalone")
        _context_local.ctx = ctx
    return ctx


def stop_program() -> None:
    """Module-level convenience mirroring ``lp.stop()``."""
    get_current_context().stop_program()


class Executable(abc.ABC):
    """A materialized unit of computation produced by a node at launch."""

    name: str = "executable"

    @abc.abstractmethod
    def run(self, context: WorkerContext) -> None:
        """Execute the service. Returns when the service is done/stopped."""


class Node(abc.ABC):
    """User-facing description of a service (the factory, not the service)."""

    def __init__(self, name: str = ""):
        self.name = name or type(self).__name__
        self._created_handles: list[Handle] = []
        # Edges: handles (to *other* nodes) this node consumes. Populated by
        # Program.add_node via collect_handles over the constructor args.
        self.input_handles: list[Handle] = []

    # ---- setup phase ------------------------------------------------------
    def create_handle(self) -> Optional[Handle]:
        """Create a handle referencing this node. None => PyNode-style."""
        return None

    def addresses(self) -> Sequence[Address]:
        """Address placeholders this node's services bind to."""
        return ()

    # ---- launch phase -----------------------------------------------------
    @abc.abstractmethod
    def to_executables(self, requirements: Optional[dict[str, Any]] = None,
                       launch_type: str = "thread") -> list[Executable]:
        """Materialize the service. Addresses are resolved by this point."""
