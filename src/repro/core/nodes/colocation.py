"""ColocationNode: force a set of nodes onto one machine (paper §4.2).

At execution time the wrapped nodes' executables run as threads of a single
executable, so their mutual communication resolves to the in-process
(shared-memory) channel. This gives the program designer node-by-node
control over locality and communication cost.
"""

from __future__ import annotations

import threading
from typing import Any, Optional

from repro.core.nodes.base import Executable, Node, WorkerContext


class _ColocatedExecutable(Executable):
    def __init__(self, name: str, inner: list[Executable]):
        self.name = name
        self._inner = inner

    def run(self, context: WorkerContext) -> None:
        errors: list[BaseException] = []
        threads = []

        def _run_one(ex: Executable):
            try:
                ex.run(context)
            except BaseException as exc:  # noqa: BLE001
                errors.append(exc)
                context.stop_program()

        for ex in self._inner:
            t = threading.Thread(target=_run_one, args=(ex,),
                                 name=f"{self.name}/{ex.name}", daemon=True)
            t.start()
            threads.append(t)
        for t in threads:
            t.join()
        if errors:
            raise errors[0]


class ColocationNode(Node):
    """Wraps nodes so their executables share one machine/process."""

    def __init__(self, *nodes: Node, name: str = "Colocation"):
        super().__init__(name=name)
        self._nodes = list(nodes)
        for n in self._nodes:
            # Handles consumed by the wrapped nodes from OUTSIDE this
            # colocation are our inputs; handles minted by wrapped nodes are
            # adopted so the program can resolve edges pointing at them.
            own = {id(h) for m in self._nodes
                   for h in getattr(m, "_created_handles", ())}
            self.input_handles.extend(
                h for h in n.input_handles if id(h) not in own)
            self._created_handles.extend(
                getattr(n, "_created_handles", ()))

    @property
    def wrapped(self) -> list[Node]:
        return self._nodes

    def addresses(self):
        out = []
        for n in self._nodes:
            out.extend(n.addresses())
        return tuple(out)

    def create_handle(self):
        return None  # use the wrapped nodes' own handles

    def to_executables(self, requirements=None, launch_type="thread"):
        inner: list[Executable] = []
        for n in self._nodes:
            inner.extend(n.to_executables(requirements, launch_type="thread"))
        return [_ColocatedExecutable(self.name, inner)]
