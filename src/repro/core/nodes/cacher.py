"""CacherNode: generic RPC-result caching layer (paper §4.2, §5.1, Fig. 2).

Wraps a handle to any CourierNode and caches RPC results for ``timeout``
seconds — the fan-in mitigation of the parameter-server example: requesters
hit the cacher; the cacher refreshes from the origin only when its copy is
stale, collapsing N requester QPS into ~1/timeout origin QPS.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Optional

from repro.core.addressing import Address
from repro.core.handles import Handle
from repro.core.nodes.base import Node
from repro.core.nodes.python import CourierHandle, _CourierExecutable


class _CacheEntry:
    __slots__ = ("value", "expires_at", "lock")

    def __init__(self):
        self.value = None
        self.expires_at = 0.0
        self.lock = threading.Lock()


class Cacher:
    """The service object behind a CacherNode.

    Exposes ``call(method, *args, **kwargs)`` plus ``__getattr__`` style
    forwarding: any public method name is served from cache when fresh,
    refreshed from the origin otherwise. Per-key locking means a stampede of
    requesters triggers exactly one origin refresh (single-flight).
    """

    def __init__(self, origin, timeout_s: float = 0.1):
        self._origin = origin
        self._timeout_s = float(timeout_s)
        self._entries: dict[Any, _CacheEntry] = {}
        self._entries_lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0}
        self._stats_lock = threading.Lock()

    def _entry(self, key) -> _CacheEntry:
        with self._entries_lock:
            entry = self._entries.get(key)
            if entry is None:
                entry = self._entries[key] = _CacheEntry()
            return entry

    def call(self, method: str, *args, **kwargs):
        key = (method, args, tuple(sorted(kwargs.items())))
        try:
            hash(key)
        except TypeError:  # unhashable args: pass straight through
            return getattr(self._origin, method)(*args, **kwargs)
        entry = self._entry(key)
        now = time.monotonic()
        if now < entry.expires_at:
            with self._stats_lock:
                self.stats["hits"] += 1
            return entry.value
        with entry.lock:  # single-flight refresh
            now = time.monotonic()
            if now < entry.expires_at:
                with self._stats_lock:
                    self.stats["hits"] += 1
                return entry.value
            value = getattr(self._origin, method)(*args, **kwargs)
            entry.value = value
            entry.expires_at = time.monotonic() + self._timeout_s
            with self._stats_lock:
                self.stats["misses"] += 1
            return value

    def cache_stats(self) -> dict:
        with self._stats_lock:
            return dict(self.stats)

    # Forward arbitrary public method names through the cache so that a
    # cacher handle is a drop-in replacement for the origin handle.
    def __getattr__(self, method: str):
        if method.startswith("_") or method == "run":
            # A cacher is a passive service: never forward the executable's
            # run() probe to the origin.
            raise AttributeError(method)

        def cached_call(*args, **kwargs):
            return self.call(method, *args, **kwargs)

        return cached_call


class CacherNode(Node):
    """Low-level caching node wrapping any CourierNode handle (paper §4.2)."""

    def __init__(self, origin: Handle, timeout_s: float = 0.1):
        super().__init__(name="Cacher")
        self._origin = origin
        self._timeout_s = timeout_s
        self.input_handles = [origin]
        self._address = Address("cacher")

    def addresses(self):
        return (self._address,)

    def create_handle(self) -> Handle:
        h = CourierHandle(self._address)
        self._created_handles.append(h)
        return h

    def to_executables(self, requirements=None, launch_type="thread"):
        return [_CourierExecutable(self.name, Cacher, (self._origin,),
                                   {"timeout_s": self._timeout_s},
                                   self._address)]
