"""PyNode and CourierNode (paper §4.1).

Both take a Python class plus constructor arguments and act as *deferred
constructors*: the class is not instantiated at setup (side effects must not
happen at graph-definition time); it is serialized with its args and
constructed at execution time, after any embedded handles are dereferenced.

``PyNode``     — no handle; cannot receive messages (pure execution /
                 communication-initiating services). Cost-saving variant.
``CourierNode`` — additionally starts a courier server exposing the public
                 methods of the constructed object; its handle dereferences
                 to an RPC client.
"""

from __future__ import annotations

import logging
from typing import Any, Optional

from repro.core import courier
from repro.core.addressing import Address, parse_endpoint
from repro.core.handles import Handle, collect_handles, map_handles
from repro.core.nodes.base import Executable, Node, WorkerContext, set_current_context

logger = logging.getLogger(__name__)


class CourierHandle(Handle):
    """Dereferences to the unified CourierClient; the endpoint scheme picks
    the transport (inproc fast path vs. gRPC on a pooled channel)."""

    def dereference(self) -> Any:
        return courier.client_for(self.address.endpoint)


def _construct(cls, args, kwargs):
    """Dereference embedded handles, then build the service object."""
    args = map_handles(list(args), lambda h: h.dereference())
    kwargs = map_handles(dict(kwargs), lambda h: h.dereference())
    return cls(*args, **kwargs)


class _PyExecutable(Executable):
    """Runs construct() then the object's run() method (if any)."""

    def __init__(self, name: str, cls, args, kwargs):
        self.name = name
        self._cls, self._args, self._kwargs = cls, args, kwargs

    def run(self, context: WorkerContext) -> None:
        set_current_context(context)
        obj = _construct(self._cls, self._args, self._kwargs)
        run_fn = getattr(obj, "run", None)
        if callable(run_fn):
            run_fn()
        else:
            context.wait_for_stop()


class _CourierExecutable(Executable):
    """Start a courier server for the object, then run()/wait (paper §4.1)."""

    def __init__(self, name: str, cls, args, kwargs, address: Address):
        self.name = name
        self._cls, self._args, self._kwargs = cls, args, kwargs
        self._address = address

    def run(self, context: WorkerContext) -> None:
        # Endpoint goes into the context *before* construction so the
        # service's __init__ can advertise itself (discovery registration).
        context.endpoint = self._address.endpoint
        set_current_context(context)
        obj = _construct(self._cls, self._args, self._kwargs)
        endpoint = self._address.endpoint
        # A "+"-joined endpoint advertises several transports for the same
        # service (e.g. shm://name+grpc://host:port from ProcessLauncher):
        # serve all of them; clients pick the first viable scheme.
        parts = parse_endpoint(endpoint)
        server = None
        try:
            if parts.inproc is not None:
                courier.inprocess.register(parts.inproc, obj)
            if parts.grpc is not None:
                host, port = parts.grpc.rsplit(":", 1)
                # handler_init: RPC handler threads get this node's context,
                # so service methods can call lp.stop_program() remotely.
                server = courier.CourierServer(
                    obj, port=int(port), host=host, shm_name=parts.shm,
                    handler_init=lambda: set_current_context(context))
                server.start()
            elif parts.shm is not None:
                raise ValueError(
                    f"shm endpoint {endpoint!r} needs a grpc:// fallback "
                    "component (launchers always emit dual endpoints)")

            run_fn = getattr(obj, "run", None)
            if callable(run_fn):
                run_fn()
            else:
                context.wait_for_stop()
        finally:
            if parts.inproc is not None:
                courier.inprocess.unregister(parts.inproc)
            if server is not None:
                server.stop()


class PyNode(Node):
    def __init__(self, cls, *args, **kwargs):
        name = getattr(cls, "__name__", "PyNode")
        super().__init__(name=name)
        self._cls, self._args, self._kwargs = cls, args, kwargs
        self.input_handles = collect_handles((args, kwargs))

    def create_handle(self) -> Optional[Handle]:
        return None  # PyNodes cannot receive messages.

    def to_executables(self, requirements=None, launch_type="thread"):
        return [_PyExecutable(self.name, self._cls, self._args, self._kwargs)]


class CourierNode(Node):
    def __init__(self, cls, *args, **kwargs):
        name = getattr(cls, "__name__", "CourierNode")
        super().__init__(name=name)
        self._cls, self._args, self._kwargs = cls, args, kwargs
        self.input_handles = collect_handles((args, kwargs))
        self._address = Address(name)

    def addresses(self):
        return (self._address,)

    def create_handle(self) -> Handle:
        h = CourierHandle(self._address)
        self._created_handles.append(h)
        return h

    def to_executables(self, requirements=None, launch_type="thread"):
        return [_CourierExecutable(self.name, self._cls, self._args,
                                   self._kwargs, self._address)]
