"""MeshWorkerNode: an SPMD (pjit) worker as a Launchpad service.

This is the TPU-pod adaptation of the paper's model (DESIGN.md §2): the
Launchpad graph is the *control plane*; inside a MeshWorkerNode the *data
plane* is a pjit-compiled step over a device mesh. The node behaves like a
CourierNode (deferred constructor, courier handle), but the resource
group's requirements carry the mesh geometry, which the launcher hands to
the service as a constructed ``jax.sharding.Mesh``::

    with p.group('learner'):
        learner = p.add_node(MeshWorkerNode(Learner, replay, ckpt_dir))
    launcher.launch(p, resources={
        'learner': {'mesh': (4, 2), 'axes': ('data', 'model')}})

The wrapped class receives ``mesh=<Mesh>`` as a keyword argument. On a real
multi-host platform the launcher would also set the jax distributed env
per host; the single-machine launchers build the mesh from local devices.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.core.addressing import Address, parse_endpoint
from repro.core.handles import Handle, collect_handles
from repro.core.nodes.base import Executable, Node, WorkerContext, set_current_context
from repro.core.nodes.python import CourierHandle, _construct


class _MeshExecutable(Executable):
    def __init__(self, name: str, cls, args, kwargs, address: Address,
                 mesh_shape, mesh_axes):
        self.name = name
        self._cls, self._args, self._kwargs = cls, args, kwargs
        self._address = address
        self._mesh_shape = mesh_shape
        self._mesh_axes = mesh_axes

    def _build_mesh(self):
        import jax
        n_need = 1
        for s in self._mesh_shape:
            n_need *= s
        n_have = len(jax.devices())
        if n_have < n_need:
            raise RuntimeError(
                f"mesh {self._mesh_shape} needs {n_need} devices, "
                f"host platform has {n_have} "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "before jax initializes, or shrink the mesh resource)")
        from repro.sharding.compat import make_mesh
        return make_mesh(self._mesh_shape, self._mesh_axes)

    def run(self, context: WorkerContext) -> None:
        from repro.core import courier
        context.endpoint = self._address.endpoint
        set_current_context(context)
        mesh = self._build_mesh()
        obj = _construct(self._cls, self._args,
                         dict(self._kwargs, mesh=mesh))
        endpoint = self._address.endpoint
        # Dual endpoints (shm://name+grpc://host:port from ProcessLauncher)
        # serve every advertised scheme, same as _CourierExecutable.
        parts = parse_endpoint(endpoint)
        server = None
        try:
            if parts.inproc is not None:
                courier.inprocess.register(parts.inproc, obj)
            if parts.grpc is not None:
                host, port = parts.grpc.rsplit(":", 1)
                server = courier.CourierServer(
                    obj, port=int(port), host=host, shm_name=parts.shm,
                    handler_init=lambda: set_current_context(context))
                server.start()
            elif parts.shm is not None:
                raise ValueError(
                    f"shm endpoint {endpoint!r} needs a grpc:// fallback "
                    "component (launchers always emit dual endpoints)")
            run_fn = getattr(obj, "run", None)
            if callable(run_fn):
                run_fn()
            else:
                context.wait_for_stop()
        finally:
            if parts.inproc is not None:
                courier.inprocess.unregister(parts.inproc)
            if server is not None:
                server.stop()


class MeshWorkerNode(Node):
    """A CourierNode whose service runs SPMD computation over a mesh."""

    DEFAULT_MESH = ((1,), ("data",))

    def __init__(self, cls, *args, **kwargs):
        name = getattr(cls, "__name__", "MeshWorker")
        super().__init__(name=name)
        self._cls, self._args, self._kwargs = cls, args, kwargs
        self.input_handles = collect_handles((args, kwargs))
        self._address = Address(name)

    def addresses(self):
        return (self._address,)

    def create_handle(self) -> Handle:
        h = CourierHandle(self._address)
        self._created_handles.append(h)
        return h

    def to_executables(self, requirements: Optional[dict[str, Any]] = None,
                       launch_type: str = "thread"):
        reqs = requirements or {}
        shape = tuple(reqs.get("mesh", self.DEFAULT_MESH[0]))
        axes = tuple(reqs.get("axes", self.DEFAULT_MESH[1]))
        if len(shape) != len(axes):
            raise ValueError(f"mesh shape {shape} / axes {axes} mismatch")
        return [_MeshExecutable(self.name, self._cls, self._args,
                                self._kwargs, self._address, shape, axes)]
