from repro.core.nodes.base import (Executable, Node, WorkerContext,
                                   get_current_context, stop_program)
from repro.core.nodes.cacher import Cacher, CacherNode
from repro.core.nodes.colocation import ColocationNode
from repro.core.nodes.mesh import MeshWorkerNode
from repro.core.nodes.python import CourierHandle, CourierNode, PyNode
from repro.core.nodes.reverb import ReverbNode

__all__ = [
    "Node", "Executable", "WorkerContext", "get_current_context",
    "stop_program", "PyNode", "CourierNode", "CourierHandle",
    "CacherNode", "Cacher", "ColocationNode", "MeshWorkerNode", "ReverbNode",
]
