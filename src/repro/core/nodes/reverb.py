"""ReverbNode: a replay/data service node (paper §4.2).

Wraps :class:`repro.data.replay.ReplayServer` — our reverb-lite — behind a
courier endpoint. "Particularly useful in reinforcement learning settings
where the dataset can itself be filled in an online fashion by data
generating processes."
"""

from __future__ import annotations

from repro.core.addressing import Address
from repro.core.handles import Handle
from repro.core.nodes.base import Node
from repro.core.nodes.python import CourierHandle, _CourierExecutable
from repro.data.replay import ReplayServer, TableConfig


class ReverbNode(Node):
    def __init__(self, tables: list[TableConfig]):
        super().__init__(name="Reverb")
        self._tables = tables
        self._address = Address("reverb")

    def addresses(self):
        return (self._address,)

    def create_handle(self) -> Handle:
        h = CourierHandle(self._address)
        self._created_handles.append(h)
        return h

    def to_executables(self, requirements=None, launch_type="thread"):
        return [_CourierExecutable(self.name, ReplayServer, (self._tables,),
                                   {}, self._address)]
