"""Resource groups (paper §2, §3.1, Listing 1).

Groups collect nodes with homogeneous requirements. Constraints are *not*
interpreted at setup — they're an opaque mapping handed to the launcher,
which applies platform-specific meaning at launch time. On our TPU-pod
adaptation the interesting resources are mesh shapes, e.g.::

    resources = {
        'learner':  {'mesh': (16, 16), 'axes': ('data', 'model')},
        'actors':   {'cpu': 2, 'ram_gb': 4},
    }
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass
class ResourceGroup:
    name: str
    # Nodes are appended by Program.add_node while the group context is open.
    nodes: list = dataclasses.field(default_factory=list)
    # Filled at launch from the user's resource mapping (Listing 1).
    requirements: Optional[dict[str, Any]] = None
    # Paper §3.1: nodes in one group must share a node type.
    node_type: Optional[type] = None

    def add(self, node) -> None:
        # Paper §3.1: "nodes added to the same resource group share a node
        # type" — this keeps the group's executables comparable. The default
        # group is exempt: it collects all *unassigned* nodes of any type.
        if self.name == DEFAULT_GROUP:
            self.nodes.append(node)
            return
        if self.node_type is None:
            self.node_type = type(node)
        elif type(node) is not self.node_type:
            raise TypeError(
                f"Resource group {self.name!r} holds nodes of type "
                f"{self.node_type.__name__}; cannot add {type(node).__name__}. "
                "Nodes in one group must share a node type (paper §3.1).")
        self.nodes.append(node)


DEFAULT_GROUP = "__default__"
