"""Handles: references to yet-to-be-constructed services (paper §2, §4).

A :class:`Handle` is returned by ``Program.add_node`` and acts as a client
to the service that the node will become. Passing a handle into another
node's constructor creates a directed edge in the program graph. During
execution each handle is *dereferenced* into a service-specific client.
"""

from __future__ import annotations

import abc
from typing import Any

from repro.core.addressing import Address


class Handle(abc.ABC):
    """Reference to a node; dereferences to a client at execution time."""

    def __init__(self, address: Address):
        self._address = address

    @property
    def address(self) -> Address:
        return self._address

    @abc.abstractmethod
    def dereference(self) -> Any:
        """Create the client object for this service (execution phase)."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self._address!r})"


def map_handles(obj: Any, fn) -> Any:
    """Recursively walk (args/kwargs-style) containers applying ``fn`` to Handles.

    Used both at setup (edge discovery) and at execution (dereferencing the
    handles embedded in a node's constructor arguments).
    """
    if isinstance(obj, Handle):
        return fn(obj)
    if isinstance(obj, (list, tuple)):
        mapped = [map_handles(v, fn) for v in obj]
        if isinstance(obj, tuple):
            # NamedTuple subclasses construct from positional fields; a
            # plain tuple() here would erase the concrete type.
            return type(obj)(*mapped) if hasattr(obj, "_fields") \
                else tuple(mapped)
        return type(obj)(mapped)
    if isinstance(obj, dict):
        return {k: map_handles(v, fn) for k, v in obj.items()}
    return obj


def collect_handles(obj: Any) -> list[Handle]:
    found: list[Handle] = []

    def _visit(h: Handle) -> Handle:
        found.append(h)
        return h

    map_handles(obj, _visit)
    return found
