"""Address placeholders and the launch-phase address table.

Paper §3.1/§3.2: during *setup*, nodes create :class:`Address` placeholders
and attach them to their handles — physical endpoints are platform specific
and unknown until launch. During *launch*, the launcher walks the program,
assigns each placeholder a concrete endpoint, and records the mapping in an
:class:`AddressTable`. Handles are serialized *after* resolution, so a
deserialized handle on a remote worker carries its resolved endpoint.
"""

from __future__ import annotations

import itertools
import threading
from typing import Optional

_uid = itertools.count()
_uid_lock = threading.Lock()


def _next_uid() -> int:
    with _uid_lock:
        return next(_uid)


class Address:
    """A placeholder for a service endpoint, resolved at launch time.

    ``endpoint`` is a URI-style string once resolved, e.g.::

        inproc://<name>      same-process registry (thread launcher / colocation)
        grpc://host:port     courier-over-gRPC (process / cluster launchers)
    """

    __slots__ = ("uid", "name", "_endpoint")

    def __init__(self, name: str = ""):
        self.uid = _next_uid()
        self.name = name
        self._endpoint: Optional[str] = None

    # -- launch phase -------------------------------------------------------
    def resolve(self, endpoint: str) -> None:
        if self._endpoint is not None and self._endpoint != endpoint:
            raise RuntimeError(
                f"Address {self.name!r} already resolved to {self._endpoint!r}; "
                f"refusing to re-resolve to {endpoint!r}")
        self._endpoint = endpoint

    # -- execution phase ----------------------------------------------------
    @property
    def endpoint(self) -> str:
        if self._endpoint is None:
            raise RuntimeError(
                f"Address {self.name!r} (uid={self.uid}) was dereferenced before "
                "launch resolved it. Handles are only usable during execution.")
        return self._endpoint

    @property
    def is_resolved(self) -> bool:
        return self._endpoint is not None

    def __repr__(self) -> str:
        state = self._endpoint if self._endpoint else "<unresolved>"
        return f"Address({self.name!r}, uid={self.uid}, endpoint={state})"

    # Addresses are serialized inside handles; preserve resolution state.
    def __getstate__(self):
        return {"uid": self.uid, "name": self.name, "endpoint": self._endpoint}

    def __setstate__(self, state):
        self.uid = state["uid"]
        self.name = state["name"]
        self._endpoint = state["endpoint"]


class AddressTable:
    """Launch-phase mapping from address uid -> endpoint (paper §3.2)."""

    def __init__(self):
        self._table: dict[int, str] = {}

    def assign(self, address: Address, endpoint: str) -> None:
        self._table[address.uid] = endpoint
        address.resolve(endpoint)

    def lookup(self, address: Address) -> str:
        return self._table[address.uid]

    def __len__(self) -> int:
        return len(self._table)

    def items(self):
        return self._table.items()
