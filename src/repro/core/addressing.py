"""Address placeholders and the launch-phase address table.

Paper §3.1/§3.2: during *setup*, nodes create :class:`Address` placeholders
and attach them to their handles — physical endpoints are platform specific
and unknown until launch. During *launch*, the launcher walks the program,
assigns each placeholder a concrete endpoint, and records the mapping in an
:class:`AddressTable`. Handles are serialized *after* resolution, so a
deserialized handle on a remote worker carries its resolved endpoint.

Endpoint schemes (see ``courier/README.md`` for the full table):

    inproc://<name>          same-process registry (thread launcher /
                             colocation)
    shm://<name>             shared-memory ring pair, same-host processes
    grpc://host:port         courier-over-gRPC (works anywhere)

An endpoint string may join several candidate URIs with ``+``, preferred
first — ``ProcessLauncher`` emits ``shm://<name>+grpc://127.0.0.1:<port>``
so same-host clients take the ring and everything else (including clients
facing a stale rendezvous left by a crashed server) falls back to gRPC.
Ports in ``grpc://`` endpoints emitted by the built-in launchers are held
by a live ``PortReservation`` socket from assignment until the server
binds, so the table never advertises a port another process can steal.
"""

from __future__ import annotations

import itertools
import threading
from typing import NamedTuple, Optional


class EndpointParts(NamedTuple):
    """A resolved endpoint split into its per-scheme components.

    Any field is ``None`` when the endpoint does not carry that scheme;
    ``grpc`` is the bare ``host:port`` with the prefix stripped.
    """

    inproc: Optional[str]
    shm: Optional[str]
    grpc: Optional[str]


def parse_endpoint(endpoint: str) -> EndpointParts:
    """Split a (possibly ``+``-joined) endpoint into scheme components.

    Server-side executables use this to serve every advertised scheme;
    raises ``ValueError`` on an unknown scheme so typos fail at launch,
    not as a mysterious connect hang.
    """
    inproc = shm = grpc = None
    for part in endpoint.split("+"):
        if part.startswith("inproc://"):
            inproc = part[len("inproc://"):]
        elif part.startswith("shm://"):
            shm = part[len("shm://"):]
        elif part.startswith("grpc://"):
            grpc = part[len("grpc://"):]
        else:
            raise ValueError(f"unknown endpoint scheme {part!r}")
    return EndpointParts(inproc, shm, grpc)

_uid = itertools.count()
_uid_lock = threading.Lock()


def _next_uid() -> int:
    with _uid_lock:
        return next(_uid)


class Address:
    """A placeholder for a service endpoint, resolved at launch time.

    ``endpoint`` is a URI-style string once resolved, e.g.::

        inproc://<name>      same-process registry (thread launcher / colocation)
        grpc://host:port     courier-over-gRPC (process / cluster launchers)
    """

    __slots__ = ("uid", "name", "_endpoint")

    def __init__(self, name: str = ""):
        self.uid = _next_uid()
        self.name = name
        self._endpoint: Optional[str] = None

    # -- launch phase -------------------------------------------------------
    def resolve(self, endpoint: str) -> None:
        if self._endpoint is not None and self._endpoint != endpoint:
            raise RuntimeError(
                f"Address {self.name!r} already resolved to {self._endpoint!r}; "
                f"refusing to re-resolve to {endpoint!r}")
        self._endpoint = endpoint

    # -- execution phase ----------------------------------------------------
    @property
    def endpoint(self) -> str:
        if self._endpoint is None:
            raise RuntimeError(
                f"Address {self.name!r} (uid={self.uid}) was dereferenced before "
                "launch resolved it. Handles are only usable during execution.")
        return self._endpoint

    @property
    def is_resolved(self) -> bool:
        return self._endpoint is not None

    def __repr__(self) -> str:
        state = self._endpoint if self._endpoint else "<unresolved>"
        return f"Address({self.name!r}, uid={self.uid}, endpoint={state})"

    # Addresses are serialized inside handles; preserve resolution state.
    def __getstate__(self):
        return {"uid": self.uid, "name": self.name, "endpoint": self._endpoint}

    def __setstate__(self, state):
        self.uid = state["uid"]
        self.name = state["name"]
        self._endpoint = state["endpoint"]


class AddressTable:
    """Launch-phase mapping from address uid -> endpoint (paper §3.2)."""

    def __init__(self):
        self._table: dict[int, str] = {}

    def assign(self, address: Address, endpoint: str) -> None:
        self._table[address.uid] = endpoint
        address.resolve(endpoint)

    def lookup(self, address: Address) -> str:
        return self._table[address.uid]

    def __len__(self) -> int:
        return len(self._table)

    def items(self):
        return self._table.items()
