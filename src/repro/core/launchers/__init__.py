from repro.core.launchers.base import Launcher
from repro.core.launchers.dryrun import DryRunLauncher, DryRunReport
from repro.core.launchers.process import ProcessLauncher
from repro.core.launchers.test import ProgramTestError, launch_and_wait
from repro.core.launchers.thread import ThreadLauncher

__all__ = [
    "Launcher", "ThreadLauncher", "ProcessLauncher", "DryRunLauncher",
    "DryRunReport", "launch_and_wait", "ProgramTestError",
]
