"""DryRunLauncher: full launch-phase processing without execution.

Validates the graph, assigns (fake but unique) addresses, materializes all
executables, and reports the topology. This is the control-plane analogue
of ``jit(...).lower().compile()`` for the data plane: it proves the program
datastructure is coherent (all handles owned, addresses resolvable, nodes
materializable) before any resources are spent.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.launchers.base import Launcher
from repro.core.nodes.base import Executable, Node


@dataclasses.dataclass
class DryRunReport:
    nodes: list[str]
    groups: dict[str, list[str]]
    executables: dict[str, int]          # node name -> count
    edges: list[tuple[str, str]]         # (consumer, producer)
    addresses: dict[str, str]            # address name/uid -> endpoint

    def summary(self) -> str:
        lines = [f"dry-run: {len(self.nodes)} nodes, "
                 f"{sum(self.executables.values())} executables, "
                 f"{len(self.edges)} edges"]
        for g, members in self.groups.items():
            lines.append(f"  group {g}: {len(members)} node(s)")
        for consumer, producer in self.edges:
            lines.append(f"  {consumer} -> {producer}")
        return "\n".join(lines)


class DryRunLauncher(Launcher):
    launch_type = "dryrun"

    def __init__(self, **kwargs):
        super().__init__(**kwargs)
        self._executables: dict[str, list[Executable]] = {}
        self._groups: dict[str, list[str]] = {}

    def _assign_address(self, node: Node, index: int) -> str:
        # Unique, never-connected endpoints: dereference would fail loudly.
        return f"grpc://dryrun.invalid:{10000 + len(self.address_table)}"

    def _execute(self, node, group_name, executables) -> None:
        self._executables[node.name] = executables
        self._groups.setdefault(group_name, []).append(node.name)

    def wait(self, timeout: Optional[float] = None) -> bool:
        return True

    def stop(self) -> None:
        pass

    def report(self) -> DryRunReport:
        program = self._program
        return DryRunReport(
            nodes=[n.name for n in program.nodes],
            groups=self._groups,
            executables={k: len(v) for k, v in self._executables.items()},
            edges=[(c.name, p.name) for c, p in program.edges()],
            addresses={f"{a}": e for a, e in self.address_table.items()},
        )
