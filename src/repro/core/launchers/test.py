"""TestLauncher: launch and wait for termination (paper §3.2).

"Optionally, the launcher can wait for or monitor the individual nodes
after they begin execution. This is especially useful in integration tests
... in which we want to verify that the distributed system performs a task
and terminates correctly."
"""

from __future__ import annotations

from typing import Any, Optional

from repro.core.fault import RestartPolicy
from repro.core.launchers.thread import ThreadLauncher
from repro.core.program import Program


class ProgramTestError(AssertionError):
    pass


def launch_and_wait(program: Program,
                    resources: Optional[dict[str, dict[str, Any]]] = None,
                    timeout_s: float = 30.0,
                    restart_policy: Optional[RestartPolicy] = None,
                    force_grpc: bool = False) -> ThreadLauncher:
    """Run a program to completion in-process; raise on failure/timeout."""
    launcher = ThreadLauncher(
        force_grpc=force_grpc,
        restart_policy=restart_policy or RestartPolicy(max_restarts=0))
    launcher.launch(program, resources)
    finished = launcher.wait(timeout=timeout_s)
    if launcher.fatal_failures:
        f = launcher.fatal_failures[0]
        raise ProgramTestError(
            f"program {program.name!r}: node {f.node_name} failed fatally"
        ) from f.error
    if not finished:
        launcher.stop()
        launcher.wait(timeout=5.0)
        raise ProgramTestError(
            f"program {program.name!r} did not terminate within {timeout_s}s")
    return launcher
