"""Launcher ABC (paper §3.2): program -> resources -> addresses -> executables.

A launcher is handed a Program plus an optional mapping from resource-group
names to platform-specific requirements (Listing 1). It

  1. validates the graph,
  2. attaches requirements to groups,
  3. performs *resource discovery* and assigns every address placeholder a
     physical endpoint (building the address table),
  4. calls ``node.to_executables()`` for each node, and
  5. hands the executables to the platform for execution, optionally
     monitoring them (with restart policies — paper §6's "the underlying
     job scheduling system has the ability to restart failing jobs").
"""

from __future__ import annotations

import abc
import logging
import threading
from typing import Any, Optional

from repro.core.addressing import AddressTable
from repro.core.fault import NodeFailure, RestartPolicy
from repro.core.nodes.base import Executable, Node
from repro.core.program import Program

logger = logging.getLogger(__name__)


class Launcher(abc.ABC):
    launch_type: str = "abstract"

    def __init__(self,
                 restart_policy: Optional[RestartPolicy] = None,
                 per_group_restart: Optional[dict[str, RestartPolicy]] = None):
        self._restart_policy = restart_policy or RestartPolicy()
        self._per_group_restart = per_group_restart or {}
        self.address_table = AddressTable()
        self.failures: list[NodeFailure] = []
        self._failures_lock = threading.Lock()

    # -- overridable per platform -------------------------------------------
    @abc.abstractmethod
    def _assign_address(self, node: Node, index: int) -> str:
        """Return a concrete endpoint for the node's index-th address."""

    @abc.abstractmethod
    def _execute(self, node: Node, group_name: str,
                 executables: list[Executable]) -> None:
        """Begin running a node's executables on the platform."""

    # -- the launch phase -----------------------------------------------------
    def launch(self, program: Program,
               resources: Optional[dict[str, dict[str, Any]]] = None) -> "Launcher":
        program.validate()
        resources = resources or {}
        unknown = set(resources) - set(program.groups)
        if unknown:
            raise ValueError(
                f"resources given for unknown groups: {sorted(unknown)}; "
                f"program has {sorted(program.groups)}")
        for gname, reqs in resources.items():
            program.groups[gname].requirements = dict(reqs)

        # Resource discovery + address assignment (before to_executables so
        # nodes can serialize resolved handles into their executables).
        for node in program.nodes:
            for i, addr in enumerate(node.addresses()):
                if not addr.is_resolved:
                    self.address_table.assign(addr, self._assign_address(node, i))

        for gname, group in program.groups.items():
            for node in group.nodes:
                executables = node.to_executables(
                    requirements=group.requirements,
                    launch_type=self.launch_type)
                self._execute(node, gname, executables)
        self._program = program
        return self

    # -- monitoring (paper §3.2 "the launcher can wait for or monitor ...") ---
    def record_failure(self, failure: NodeFailure) -> None:
        with self._failures_lock:
            self.failures.append(failure)
        logger.warning("node %s failed (restarts=%d, fatal=%s): %r",
                       failure.node_name, failure.restarts, failure.fatal,
                       failure.error)

    def policy_for(self, group_name: str) -> RestartPolicy:
        return self._per_group_restart.get(group_name, self._restart_policy)

    @abc.abstractmethod
    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the program terminates. True if it did."""

    @abc.abstractmethod
    def stop(self) -> None:
        """Request cooperative shutdown of every service."""
