"""ThreadLauncher: every executable is a thread in this process.

This mirrors the open-sourced Launchpad's single-machine launcher. Services
communicate over the in-process courier channel (``inproc://``) unless
``force_grpc=True``, which binds real gRPC servers on localhost — useful
for measuring the RPC overhead the paper discusses, without processes.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Optional

from repro.core.fault import NodeFailure
from repro.core.launchers.base import Launcher
from repro.core.nodes.base import Executable, Node, WorkerContext


def pick_free_port() -> int:
    """Ask the kernel for a free port, then release it.

    Inherently racy (pick-then-bind TOCTOU): another process can grab the
    port between return and the server's bind. Launchers should use
    :class:`PortReservation` instead, which *holds* the port until the
    server binds; this stays for callers that only need a probably-free
    port.
    """
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


class PortReservation:
    """A port the kernel bound for us and that we keep holding.

    Closes the pick-free-port TOCTOU window: the reservation socket is
    bound with SO_REUSEPORT and *stays open* (never listening, so it
    receives no connections) while the courier server — which also binds
    with SO_REUSEPORT (pinned in ``_GRPC_OPTIONS``) — binds the same
    port. No other port-0 allocation can be handed this port while the
    reservation lives, so the endpoint written into the address table is
    the port the server actually binds. On platforms without
    SO_REUSEPORT this degrades to the legacy racy pick.
    """

    def __init__(self, host: str = "127.0.0.1"):
        self._sock: Optional[socket.socket] = None
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        reuseport = getattr(socket, "SO_REUSEPORT", None)
        if reuseport is None:  # pragma: no cover - non-Linux fallback
            s.close()
            self.port = pick_free_port()
            return
        s.setsockopt(socket.SOL_SOCKET, reuseport, 1)
        s.bind((host, 0))
        self.port = s.getsockname()[1]
        self._sock = s

    def release(self) -> None:
        if self._sock is not None:
            self._sock.close()
            self._sock = None


class ThreadLauncher(Launcher):
    launch_type = "thread"

    def __init__(self, force_grpc: bool = False, **kwargs):
        super().__init__(**kwargs)
        self._force_grpc = force_grpc
        self._stop_event = threading.Event()
        self._threads: list[threading.Thread] = []
        self._reservations: list[PortReservation] = []

    # -- addresses ------------------------------------------------------------
    def _assign_address(self, node: Node, index: int) -> str:
        if self._force_grpc:
            # Reservation held until stop(): the port in the address table
            # is the port the server binds (no pick-then-bind race).
            res = PortReservation()
            self._reservations.append(res)
            return f"grpc://127.0.0.1:{res.port}"
        return f"inproc://{node.name}/{index}"

    # -- execution ------------------------------------------------------------
    def _execute(self, node: Node, group_name: str,
                 executables: list[Executable]) -> None:
        policy = self.policy_for(group_name)

        for ex in executables:
            def _runner(ex: Executable = ex, node_name: str = node.name):
                restarts = 0
                while not self._stop_event.is_set():
                    ctx = WorkerContext(node_name=node_name,
                                        stop_event=self._stop_event,
                                        stop_program_fn=self.stop)
                    try:
                        ex.run(ctx)
                        return  # clean completion
                    except BaseException as exc:  # noqa: BLE001
                        fatal = not policy.allows(restarts)
                        self.record_failure(NodeFailure(
                            node_name=node_name, error=exc,
                            restarts=restarts, fatal=fatal))
                        if fatal:
                            # A node out of restart budget takes the program
                            # down (fail-fast beats a silently degraded job).
                            self.stop()
                            return
                        time.sleep(policy.backoff_for(restarts))
                        restarts += 1

            t = threading.Thread(target=_runner, name=f"lp/{ex.name}", daemon=True)
            self._threads.append(t)
            t.start()

    # -- lifecycle --------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for t in self._threads:
            remaining = None
            if deadline is not None:
                remaining = max(0.0, deadline - time.monotonic())
                if remaining == 0.0:
                    return False
            t.join(remaining)
            if t.is_alive():
                return False
        # Clean completion without stop(): the reserved ports' job is done
        # once every server has exited.
        for res in self._reservations:
            res.release()
        self._reservations.clear()
        return True

    def stop(self) -> None:
        self._stop_event.set()
        for res in self._reservations:
            res.release()
        self._reservations.clear()

    @property
    def fatal_failures(self) -> list[NodeFailure]:
        return [f for f in self.failures if f.fatal]
