"""ProcessLauncher: executables run as OS processes, courier over gRPC.

The closest single-machine analogue of a cluster launcher: every service is
its own process with a real network endpoint, so serialization, transport
and failure isolation behave like the distributed setting. A shared
``multiprocessing.Event`` implements cooperative stop in both directions
(parent -> children and any child's ``stop_program()`` -> everyone).

Fault tolerance: a monitor thread watches child processes; non-zero exits
are restarted per the group's RestartPolicy (paper §6 — scheduler restarts;
stateful services are expected to self-restore from checkpoints).
"""

from __future__ import annotations

import multiprocessing as mp
import os
import threading
import time
import uuid
from typing import Optional

import cloudpickle

from repro.core.courier import shm as courier_shm
from repro.core.fault import NodeFailure
from repro.core.launchers.base import Launcher
from repro.core.launchers.thread import PortReservation
from repro.core.nodes.base import Executable, Node, WorkerContext


def _child_main(payload: bytes, stop_event, node_name: str) -> None:
    """Child entry point. ``payload`` is a cloudpickled executable."""
    executable: Executable = cloudpickle.loads(payload)
    ctx = WorkerContext(node_name=node_name, stop_event=stop_event,
                        stop_program_fn=stop_event.set)
    executable.run(ctx)


class _Managed:
    __slots__ = ("node_name", "group", "payload", "process", "restarts", "done")

    def __init__(self, node_name: str, group: str, payload: bytes):
        self.node_name = node_name
        self.group = group
        self.payload = payload
        self.process: Optional[mp.Process] = None
        self.restarts = 0
        self.done = False


class ProcessLauncher(Launcher):
    launch_type = "process"

    def __init__(self, start_method: str = "fork", monitor_interval_s: float = 0.05,
                 **kwargs):
        super().__init__(**kwargs)
        self._mp = mp.get_context(start_method)
        self._stop_event = self._mp.Event()
        self._managed: list[_Managed] = []
        self._monitor_interval_s = monitor_interval_s
        self._monitor: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._reservations: list[PortReservation] = []
        self._shm_names: list[str] = []

    # -- addresses ------------------------------------------------------------
    def _assign_address(self, node: Node, index: int) -> str:
        # Dual endpoint: same-host peers connect over the shared-memory
        # ring (shm.py); anything that can't — no listener yet after the
        # connect grace, a stale listener from a crashed node, a remote
        # host — falls back to gRPC. The port reservation is held until
        # terminate(), so the advertised port is the one the child binds.
        res = PortReservation()
        self._reservations.append(res)
        grpc_ep = f"grpc://127.0.0.1:{res.port}"
        if not courier_shm.supported():  # pragma: no cover - non-POSIX
            return grpc_ep
        name = f"lp{os.getpid():x}u{index}x{uuid.uuid4().hex[:8]}"
        self._shm_names.append(name)
        return f"shm://{name}+{grpc_ep}"

    # -- execution ---------------------------------------------------------------
    def _spawn(self, managed: _Managed) -> None:
        p = self._mp.Process(
            target=_child_main,
            args=(managed.payload, self._stop_event, managed.node_name),
            name=f"lp/{managed.node_name}", daemon=True)
        p.start()
        managed.process = p

    def _execute(self, node: Node, group_name: str,
                 executables: list[Executable]) -> None:
        for ex in executables:
            managed = _Managed(node.name, group_name, cloudpickle.dumps(ex))
            self._managed.append(managed)
            self._spawn(managed)
        if self._monitor is None:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             name="lp/monitor", daemon=True)
            self._monitor.start()

    # -- monitoring / restarts --------------------------------------------------
    def _monitor_loop(self) -> None:
        # The monitor is the single source of truth for node lifecycle:
        # it marks clean exits done, restarts failures per policy, and only
        # then may wait() observe completion (avoids a race where wait()
        # sees a dead-but-restartable process and declares the program over).
        while True:
            all_done = True
            with self._lock:
                managed_list = list(self._managed)
            for m in managed_list:
                if m.done or m.process is None:
                    continue
                if m.process.is_alive():
                    all_done = False
                    continue
                code = m.process.exitcode
                if code == 0 or self._stop_event.is_set():
                    m.done = True
                    continue
                policy = self.policy_for(m.group)
                fatal = not policy.allows(m.restarts)
                self.record_failure(NodeFailure(
                    node_name=m.node_name,
                    error=RuntimeError(f"process exited with code {code}"),
                    restarts=m.restarts, fatal=fatal))
                if fatal:
                    self.stop()
                    m.done = True
                else:
                    time.sleep(policy.backoff_for(m.restarts))
                    m.restarts += 1
                    self._spawn(m)
                    all_done = False
            if all_done or self._stop_event.is_set():
                return
            time.sleep(self._monitor_interval_s)

    # -- lifecycle -----------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        done = self._wait_inner(timeout)
        if done:
            self._release_resources()
        return done

    def _wait_inner(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            # Completion is judged by the monitor's m.done marks so that a
            # crashed-but-restartable node is never mistaken for "finished".
            pending = [m for m in self._managed if not m.done]
            alive = [m for m in pending
                     if m.process is not None and m.process.is_alive()]
            if not pending:
                return True
            if not alive and all(
                    m.process is not None and m.process.exitcode == 0
                    for m in pending):
                # Clean exits the monitor hasn't marked yet.
                if self._monitor is not None and not self._monitor.is_alive():
                    return True
            if self._stop_event.is_set():
                # Grace period, then hard-terminate stragglers.
                grace_deadline = time.monotonic() + 2.0
                while time.monotonic() < grace_deadline:
                    if not any(m.process.is_alive() for m in alive):
                        return True
                    time.sleep(0.02)
                for m in alive:
                    if m.process.is_alive():
                        m.process.terminate()
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            time.sleep(0.02)

    def stop(self) -> None:
        self._stop_event.set()

    def _release_resources(self) -> None:
        for res in self._reservations:
            res.release()
        self._reservations.clear()
        # Hard-killed children never ran their listener teardown: sweep
        # their rendezvous dirs so later clients see "absent", not "stale".
        for name in self._shm_names:
            courier_shm.cleanup(name)

    def terminate(self) -> None:
        """Hard kill (used by tests' teardown)."""
        self._stop_event.set()
        for m in self._managed:
            if m.process is not None and m.process.is_alive():
                m.process.terminate()
        for m in self._managed:
            if m.process is not None:
                m.process.join(timeout=2.0)
        self._release_resources()
