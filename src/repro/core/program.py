"""The Launchpad Program: a directed graph of service nodes (paper §2, §3.1).

Setup-phase API::

    p = Program('producer-consumer')
    with p.group('producer'):
        h1 = p.add_node(CourierNode(Range, 0, 10))
        h2 = p.add_node(CourierNode(Range, 10, 20))
    with p.group('consumer'):
        p.add_node(CourierNode(Consumer, [h1, h2]))

Edges are created implicitly when one node's handle is passed to another
node's constructor; the edge originates at the *receiving* node (the one
initiating communication).
"""

from __future__ import annotations

import contextlib
from typing import Optional

from repro.core.handles import Handle
from repro.core.nodes.base import Node
from repro.core.resources import DEFAULT_GROUP, ResourceGroup


class Program:
    def __init__(self, name: str):
        self.name = name
        self.groups: dict[str, ResourceGroup] = {}
        self._current_group: Optional[str] = None
        # Graph bookkeeping: node -> handle (or None), and handle -> owner node.
        self.nodes: list[Node] = []
        self._handle_owner: dict[int, Node] = {}  # id(handle) -> node

    # ---- resource groups ---------------------------------------------------
    @contextlib.contextmanager
    def group(self, name: str):
        """Context manager assigning added nodes to a resource group."""
        if name == DEFAULT_GROUP:
            raise ValueError(f"{DEFAULT_GROUP!r} is reserved")
        if self._current_group is not None:
            raise RuntimeError("Resource groups cannot be nested")
        self._current_group = name
        try:
            yield
        finally:
            self._current_group = None

    def _group_for(self, name: str) -> ResourceGroup:
        if name not in self.groups:
            self.groups[name] = ResourceGroup(name)
        return self.groups[name]

    # ---- graph construction --------------------------------------------------
    def add_node(self, node: Node, label: Optional[str] = None) -> Optional[Handle]:
        """Add a node to the graph; returns a handle referencing it (or None)."""
        if node in self.nodes:
            raise ValueError(f"Node {node.name!r} was already added")
        group_name = self._current_group or DEFAULT_GROUP
        group = self._group_for(group_name)
        group.add(node)
        if label:
            node.name = label
        # Disambiguate node names within the program (useful for addresses).
        node.name = f"{group_name}/{node.name}_{len(self.nodes)}"
        self.nodes.append(node)

        handle = node.create_handle()
        if handle is not None:
            self._handle_owner[id(handle)] = node
        # Adopt handles minted before add_node (e.g. handles of nodes
        # wrapped in a ColocationNode, created to wire them to each other).
        for h in getattr(node, "_created_handles", ()):
            self._handle_owner.setdefault(id(h), node)
        return handle

    # ---- introspection -------------------------------------------------------
    def edges(self) -> list[tuple[Node, Node]]:
        """(consumer, producer) pairs — the consumer initiates communication."""
        out = []
        for node in self.nodes:
            for h in node.input_handles:
                owner = self._handle_owner.get(id(h))
                if owner is not None:
                    out.append((node, owner))
        return out

    def owner_of(self, handle: Handle) -> Optional[Node]:
        return self._handle_owner.get(id(handle))

    def validate(self) -> None:
        """Structural checks run by launchers before anything starts."""
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"Duplicate node names in program: {names}")
        for node in self.nodes:
            for h in node.input_handles:
                if id(h) not in self._handle_owner:
                    raise ValueError(
                        f"Node {node.name!r} consumes a handle that does not "
                        "belong to any node in this program")

    def __repr__(self) -> str:
        lines = [f"Program({self.name!r})"]
        for gname, group in self.groups.items():
            lines.append(f"  group {gname}:")
            for node in group.nodes:
                deps = [self._handle_owner[id(h)].name
                        for h in node.input_handles
                        if id(h) in self._handle_owner]
                suffix = f" <- {deps}" if deps else ""
                lines.append(f"    {node.name} [{type(node).__name__}]{suffix}")
        return "\n".join(lines)
