"""Courier: the RPC layer under Launchpad handles (paper §4, footnote 2).

Layered as: ``CourierClient`` (proxy sugar) over a pluggable
:class:`Transport` (``GrpcTransport`` / ``ShmTransport`` /
``InProcTransport``) over the framed zero-copy wire format
(``serialization``). See README.md here.
"""

from __future__ import annotations

from repro.core.courier import inprocess, shm
from repro.core.courier.client import CourierClient
from repro.core.courier.serialization import RemoteError, materialize
from repro.core.courier.server import CourierServer
from repro.core.courier.transport import (GrpcTransport, InProcTransport,
                                          ShmTransport, Transport,
                                          channel_pool_stats, make_transport)


def client_for(endpoint: str) -> CourierClient:
    """Build the unified client over the most appropriate transport.

    ``inproc://name`` -> same-process direct transport (colocated services)
    ``shm://name`` -> shared-memory ring pair (same-host processes)
    ``grpc://host:port`` -> courier-over-gRPC on a pooled channel

    Endpoints may list several candidates joined by ``+`` (preferred
    first); the first viable one wins, e.g. ``shm://n+grpc://h:p`` uses
    the ring on the server's host and gRPC everywhere else.
    """
    return CourierClient(endpoint)


__all__ = [
    "CourierClient",
    "CourierServer",
    "GrpcTransport",
    "InProcTransport",
    "RemoteError",
    "ShmTransport",
    "Transport",
    "channel_pool_stats",
    "client_for",
    "inprocess",
    "make_transport",
    "materialize",
    "shm",
]
