"""Courier: the RPC layer under Launchpad handles (paper §4, footnote 2)."""

from __future__ import annotations

from typing import Any

from repro.core.courier import inprocess
from repro.core.courier.client import CourierClient
from repro.core.courier.serialization import RemoteError
from repro.core.courier.server import CourierServer


def client_for(endpoint: str) -> Any:
    """Build the most appropriate client for a resolved endpoint.

    ``inproc://name`` -> shared-memory direct client (colocated services)
    ``grpc://host:port`` -> courier-over-gRPC client
    """
    if endpoint.startswith("inproc://"):
        return inprocess.InProcessClient(endpoint[len("inproc://"):])
    if endpoint.startswith("grpc://"):
        return CourierClient(endpoint)
    raise ValueError(f"unknown courier endpoint scheme: {endpoint!r}")


__all__ = [
    "CourierClient",
    "CourierServer",
    "RemoteError",
    "client_for",
    "inprocess",
]
