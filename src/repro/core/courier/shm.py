"""Shared-memory ring-buffer channel for same-host courier traffic.

Two nodes the process launcher placed on one host still paid the full gRPC
stack for every call (~2000x the in-process cost for a ping — see
BENCH_rpc.json). This module moves framed courier messages between
same-host processes over ``multiprocessing.shared_memory`` instead:

* **Ring** — one SPSC byte ring per direction. The writer owns ``wpos``,
  the reader owns ``rpos`` (each on its own cache line, published after
  the payload), so neither side ever takes a cross-process lock on the
  data path. Records are length-prefixed and contiguous; a record that
  would straddle the wrap point is preceded by a pad record both sides
  skip deterministically.
* **Slot pool** — a message larger than ``SPILL_THRESHOLD`` is
  scatter-gathered (``serialization.write_framed_into``) into one slot
  of a per-direction *slot pool* side segment (``SLOT_COUNT`` fixed-
  offset slots, free map in the segment header) and only a tiny
  reference record enters the control ring, so the ring stays small
  while 8 MiB tensors move at memcpy speed. The pool is created lazily,
  reused for the connection's lifetime (segment creation and first-touch
  page faults cost milliseconds on the kernels we deploy on), and
  regrown under a versioned name when a bigger message arrives; slots
  sit at *fixed* offsets — cycling a multi-MiB ring through the cache
  measures ~3x slower than rewriting a few hot regions.
* **Zero-copy receive** — the reader decodes a slot *in place*
  (``serialization.loads_owned``): decoded arrays are read-only views
  aliasing the slot, pinned by a :class:`SlotLease`. The slot returns to
  the pool when the decoded message is garbage-collected (or the lease
  explicitly released) — **not** when the receive returns — so the
  receive path never copies the payload, and with ``SLOT_COUNT`` slots
  per direction, pipelined large messages overlap instead of
  serializing on one slot. A consumer that retains a decoded tensor
  long-term must ``np.copy`` it (or ``serialization.materialize`` the
  message) or it starves the sender's pool.
* **Doorbell** — waiting sides use an adaptive spin-then-micro-sleep loop
  (a portable stand-in for a futex: hot peers rendezvous in microseconds,
  idle peers cost ~0 CPU). Position loads/stores are 8-byte aligned, so
  they are single movs on x86-64/arm64 — published last, read first.
* **Rendezvous** — a server advertises under
  ``$TMPDIR/courier-shm/<name>/listener.json``; a client creates the two
  rings, drops a ``<conn>.connect`` file, and waits for the listener's
  HELLO record. Liveness is pid-based: a stale directory left by a
  crashed server is detected immediately (``probe`` -> "stale") so
  callers can fall back to gRPC instead of deadlocking.

Record layout (little-endian)::

    size:u32 | kind:u32 | req_id:u64 | body[size - 16]

``size == 0`` marks a pad record (skip to the wrap point). The body is a
standard framed serialization message, or a slot-pool reference::

    \xc5\x03 | name_len:u16 | segment_name | slot:u32 | total:u64
"""

from __future__ import annotations

import contextlib
import glob
import json
import os
import socket
import struct
import tempfile
import threading
import time
import uuid
from concurrent import futures
from multiprocessing import resource_tracker, shared_memory
from typing import Any, Callable, Optional

from repro.core.courier import serialization as ser

# ---- tunables (module-level so tests/benchmarks can shrink them) ------------

RING_CAPACITY = 1 << 20        # per-direction control-ring data bytes
SPILL_THRESHOLD = 96 * 1024    # messages above this go to the slot pool
SLOT_COUNT = 4                 # fixed-offset slots per pool (per direction)
SLOT_HEADROOM = 1.5            # pool slots are sized to msg_size * this
CONNECT_WAIT_S = 5.0           # how long a client waits for the listener
ACCEPT_WAIT_S = 5.0            # how long a client waits for HELLO
_POLL_ACCEPT_S = 0.01          # listener connect-dir poll interval

_POOL_GROW_GRACE_S = 0.02      # full-pool wait before expanding the pool

# _doorbell_wait backoff schedule (module-level so tests can shrink it).
_SPIN_HOT = 1600               # hot-phase checks (sched_yield every 4th)
_SPIN_MICRO = 6400             # then micro-sleeps until this many checks
_SLEEP_MICRO_S = 0.00002
_SLEEP_IDLE_S = 0.0002

# ---- record kinds ------------------------------------------------------------

KIND_HELLO = 0
KIND_CALL = 1
KIND_BATCH = 2
KIND_REPLY = 3
KIND_BATCH_REPLY = 4
KIND_CLOSE = 5

_REC = struct.Struct("<IIQ")       # size (incl. header), kind, req_id
_REF_MAGIC = b"\xc5\x03"           # pool reference: namelen|name|slot|total
_REF_NAME = struct.Struct("<H")    # segment-name length
_REF_TAIL = struct.Struct("<IQ")   # slot index, framed-message length

# Ring segment header: wpos and rpos on separate cache lines; one closed
# byte per side so neither performs a read-modify-write on shared state.
_WPOS_OFF = 0
_RPOS_OFF = 64
_WCLOSED_OFF = 128
_RCLOSED_OFF = 129
_DATA_OFF = 192
_POS = struct.Struct("<Q")

# Slot-pool segment header (see SlotPool): slot count, slot size, a
# reader-closed byte, then one state byte per slot (0 free / 1 leased).
# Slot data starts page-aligned so slots never share a page with header
# state the two sides poll.
_PH_NSLOTS = struct.Struct("<I")   # at offset 0
_PH_SLOTSZ_OFF = 8                 # u64 via _POS
_PH_RCLOSED_OFF = 16
_PH_STATES_OFF = 64
_POOL_DATA_OFF = 4096
_SLOT_ALIGN = 4096


class RingClosed(ConnectionError):
    """The peer closed its end of the ring (or went away)."""


class DecodeFailure:
    """A message that arrived intact but failed to unpickle; carries the
    decode exception while preserving reply correlation."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class ShmConnectError(ConnectionError):
    """Could not establish a shared-memory connection (caller may fall
    back to another transport)."""


def supported() -> bool:
    """Shared-memory transport is POSIX-only (named segments + pid probes)."""
    return os.name == "posix"


def _untrack(shm: shared_memory.SharedMemory) -> None:
    # Python <=3.12 registers every attach with the resource tracker, which
    # then unlinks segments owned by *other* processes at exit (bpo-39959).
    # We manage unlink ourselves, so take the segment out of the tracker.
    with contextlib.suppress(Exception):
        resource_tracker.unregister(shm._name, "shared_memory")  # noqa: SLF001


def _unlink_quiet(name: str) -> None:
    # shm_unlink without SharedMemory.unlink()'s resource-tracker
    # unregister (we already untracked; a second unregister raises in the
    # tracker daemon). ``name`` is the public segment name (no slash).
    try:
        import _posixshmem  # stdlib backend of shared_memory on POSIX
        with contextlib.suppress(FileNotFoundError):
            _posixshmem.shm_unlink("/" + name.lstrip("/"))
    except ImportError:  # pragma: no cover - non-POSIX
        with contextlib.suppress(Exception):
            shared_memory.SharedMemory(name=name).unlink()


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def _doorbell_wait(ready: Callable[[], bool], *,
                   deadline: Optional[float],
                   give_up: Callable[[], Optional[BaseException]]) -> bool:
    """Adaptive wait: poll with periodic yields, then micro-sleeps.

    The hot phase checks ``ready`` back-to-back and releases the GIL with
    ``time.sleep(0)`` (sched_yield) every 4th check. Yielding on *every*
    check paid a syscall per sub-microsecond poll and put the shm ping at
    ~240us; polling between yields brings hot rendezvous down to the
    check granularity itself while still never holding the GIL longer
    than a few checks (a pure Python spin would hold it for a full switch
    interval, ~5ms, convoying the very thread that would satisfy the wait
    when sender and waiter share a process). After the hot phase come
    20us micro-sleeps, then 200us naps so long-idle waiters cost ~0 CPU.
    Returns False on deadline; raises whatever ``give_up`` supplies
    (peer-closed / peer-dead detection, throttled — it may involve a
    pid-probe syscall)."""
    spins = 0
    while not ready():
        if spins % 128 == 0:
            exc = give_up()
            if exc is not None:
                raise exc
            if deadline is not None and time.monotonic() >= deadline:
                return False
        spins += 1
        if spins < _SPIN_HOT:
            if spins % 4 == 0:
                time.sleep(0)
        elif spins < _SPIN_MICRO:
            time.sleep(_SLEEP_MICRO_S)
        else:
            time.sleep(_SLEEP_IDLE_S)
    return True


class Ring:
    """Single-producer single-consumer byte ring over one shm segment.

    Positions are monotonic u64s; the writer publishes ``wpos`` only after
    the record bytes are in place, the reader publishes ``rpos`` only after
    copying a record out, so each position has exactly one writer and the
    data path needs no cross-process lock. In-process concurrency (several
    client threads sending) is serialized by ``_wlock``.
    """

    def __init__(self, shm: shared_memory.SharedMemory, owner: bool):
        self._shm = shm
        self._buf = shm.buf
        self._cap = shm.size - _DATA_OFF
        self._owner = owner
        self._wlock = threading.Lock()
        self._released = False

    # -- construction --------------------------------------------------------
    @classmethod
    def create(cls, name: str, capacity: int = RING_CAPACITY) -> "Ring":
        shm = shared_memory.SharedMemory(name=name, create=True,
                                         size=capacity + _DATA_OFF)
        _untrack(shm)
        return cls(shm, owner=True)

    @classmethod
    def attach(cls, name: str) -> "Ring":
        shm = shared_memory.SharedMemory(name=name)
        _untrack(shm)
        return cls(shm, owner=False)

    @property
    def name(self) -> str:
        return self._shm.name

    # -- header accessors ----------------------------------------------------
    def _load(self, off: int) -> int:
        return _POS.unpack_from(self._buf, off)[0]

    def _store(self, off: int, value: int) -> None:
        _POS.pack_into(self._buf, off, value)

    def close_write(self) -> None:
        self._buf[_WCLOSED_OFF] = 1

    def close_read(self) -> None:
        self._buf[_RCLOSED_OFF] = 1

    @property
    def writer_closed(self) -> bool:
        return self._buf[_WCLOSED_OFF] != 0

    @property
    def reader_closed(self) -> bool:
        return self._buf[_RCLOSED_OFF] != 0

    def has_backlog(self) -> bool:
        """More records waiting? (reader-side heuristic; racy by nature)"""
        return self._load(_WPOS_OFF) != self._load(_RPOS_OFF)

    # -- data path -----------------------------------------------------------
    def write(self, kind: int, req_id: int, chunks,
              timeout: Optional[float] = None,
              give_up: Optional[Callable[[], Optional[BaseException]]] = None
              ) -> None:
        """Gather ``chunks`` into one contiguous record. Blocks while the
        ring is full; raises :class:`RingClosed` if the reader is gone."""
        views = [memoryview(c).cast("B") for c in chunks]
        total = _REC.size + sum(v.nbytes for v in views)
        if total > self._cap:
            raise ValueError(
                f"record of {total} bytes exceeds ring capacity {self._cap} "
                "(spill threshold misconfigured?)")
        deadline = None if timeout is None else time.monotonic() + timeout

        def _give_up():
            if self.reader_closed:
                return RingClosed("ring reader closed")
            return give_up() if give_up is not None else None

        with self._wlock:
            wpos = self._load(_WPOS_OFF)
            while True:
                off = wpos % self._cap
                rem = self._cap - off
                # Bytes needed *now*: the record, plus the tail bytes a pad
                # (or implicit skip) would consume first.
                need = rem + total if rem < total else total
                if not _doorbell_wait(
                        lambda: self._cap - (wpos - self._load(_RPOS_OFF))
                        >= need,
                        deadline=deadline, give_up=_give_up):
                    raise TimeoutError("ring full")
                if rem < _REC.size:
                    # Tail too small even for a header: both sides skip it.
                    wpos += rem
                    self._store(_WPOS_OFF, wpos)
                    continue
                if rem < total:
                    # Pad record: reader jumps to the wrap point.
                    _REC.pack_into(self._buf, _DATA_OFF + off, 0, 0, 0)
                    wpos += rem
                    self._store(_WPOS_OFF, wpos)
                    continue
                pos = _DATA_OFF + off
                _REC.pack_into(self._buf, pos, total, kind, req_id)
                pos += _REC.size
                for v in views:
                    ser.copy_into(self._buf, pos, v)
                    pos += v.nbytes
                # Publish *after* the payload is in place.
                self._store(_WPOS_OFF, wpos + total)
                return

    def read(self, timeout: Optional[float] = None,
             give_up: Optional[Callable[[], Optional[BaseException]]] = None
             ) -> Optional[tuple[int, int, bytes]]:
        """Pop one record as ``(kind, req_id, body)``; the body is copied
        out so ring space recycles immediately. ``None`` on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout

        def _give_up():
            if self.writer_closed and self._load(_WPOS_OFF) == rpos:
                return RingClosed("ring writer closed")
            return give_up() if give_up is not None else None

        rpos = self._load(_RPOS_OFF)
        while True:
            if not _doorbell_wait(lambda: self._load(_WPOS_OFF) != rpos,
                                  deadline=deadline, give_up=_give_up):
                return None
            off = rpos % self._cap
            rem = self._cap - off
            if rem < _REC.size:
                rpos += rem
                self._store(_RPOS_OFF, rpos)
                continue
            size, kind, req_id = _REC.unpack_from(self._buf, _DATA_OFF + off)
            if size == 0:  # pad
                rpos += rem
                self._store(_RPOS_OFF, rpos)
                continue
            start = _DATA_OFF + off + _REC.size
            body = ser.read_copy(self._buf, start, size - _REC.size)
            rpos += size
            self._store(_RPOS_OFF, rpos)
            return kind, req_id, body

    # -- lifecycle -----------------------------------------------------------
    def release(self, unlink: bool = False) -> None:
        """Drop our mapping (and the name, if ``unlink``). Idempotent."""
        if self._released:
            return
        self._released = True
        self._buf = None  # release the exported memoryview before close()
        name = self._shm.name
        with contextlib.suppress(Exception):
            self._shm.close()
        if unlink:
            _unlink_quiet(name)


class SlotLease:
    """Pins one :class:`SlotPool` slot under a decoded message.

    ``serialization.loads_owned`` threads the lease beneath every decoded
    array, so the slot returns to the pool exactly when the consumer
    drops the decoded object graph (CPython refcounting makes that
    prompt) — or earlier, via an explicit :meth:`release`. Idempotent;
    ``__del__`` is the GC fallback.
    """

    __slots__ = ("_pool", "_index", "_lock", "__weakref__")

    def __init__(self, pool: "SlotPool", index: int):
        self._pool = pool
        self._index = index
        # Leases release from arbitrary threads (GC of the decoded graph,
        # explicit release); the swap below must not double-free the slot.
        self._lock = threading.Lock()

    @property
    def released(self) -> bool:
        return self._pool is None

    def release(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool._release_slot(self._index)  # noqa: SLF001 - by design

    def __del__(self):
        try:
            self.release()
        except Exception:  # interpreter shutdown: globals may be gone
            pass


class SlotPool:
    """N fixed-offset one-message slots over one shm segment, with a
    header-tracked free map and a lease-based free protocol.

    Each state byte has exactly one writer per transition: the segment
    *writer* claims a free slot (0 -> 1, under the channel send lock),
    gathers the message into it, and publishes the control-ring
    reference only afterwards, so the reader never sees a half-written
    slot. The *reader* decodes the slot in place and returns it
    (1 -> 0) when the decoded message's :class:`SlotLease` is released —
    by GC of the object graph, not by the receive call — which is what
    makes the receive path zero-copy and lets ``SLOT_COUNT`` large
    messages be in flight per direction at once.

    The segment name is unlinked eagerly on :meth:`release`; the mapping
    itself is dropped only when the last outstanding lease dies, so a
    decoded view retained past transport close stays valid (POSIX keeps
    unlinked memory alive until the final ``munmap``).
    """

    def __init__(self, shm: shared_memory.SharedMemory, owner: bool):
        self._shm = shm
        self._buf = shm.buf
        self._name = shm.name
        self._owner = owner
        self.nslots = _PH_NSLOTS.unpack_from(shm.buf, 0)[0]
        self.slot_size = _POS.unpack_from(shm.buf, _PH_SLOTSZ_OFF)[0]
        self._lock = threading.Lock()  # guards lease count + close
        self._outstanding = 0
        self._released = False
        self._close_deferred = False

    @classmethod
    def create(cls, name: str, slot_size: int,
               nslots: Optional[int] = None) -> "SlotPool":
        nslots = SLOT_COUNT if nslots is None else nslots
        slot_size = -(-slot_size // _SLOT_ALIGN) * _SLOT_ALIGN
        shm = shared_memory.SharedMemory(
            name=name, create=True,
            size=_POOL_DATA_OFF + nslots * slot_size)
        _untrack(shm)
        _PH_NSLOTS.pack_into(shm.buf, 0, nslots)
        _POS.pack_into(shm.buf, _PH_SLOTSZ_OFF, slot_size)
        return cls(shm, owner=True)

    @classmethod
    def attach(cls, name: str) -> "SlotPool":
        shm = shared_memory.SharedMemory(name=name)
        _untrack(shm)
        return cls(shm, owner=False)

    @property
    def name(self) -> str:
        return self._name

    def _data_off(self, index: int) -> int:
        return _POOL_DATA_OFF + index * self.slot_size

    @property
    def all_free(self) -> bool:
        buf = self._buf
        return buf is not None and all(
            buf[_PH_STATES_OFF + i] == 0 for i in range(self.nslots))

    # -- writer side ---------------------------------------------------------
    def acquire(self, timeout: Optional[float] = None,
                give_up: Optional[Callable] = None) -> int:
        """Claim a free slot (0 -> 1); blocks while all are leased by the
        consumer. Caller must serialize acquires (the channel send lock)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        buf = self._buf

        def _any_free():
            return any(buf[_PH_STATES_OFF + i] == 0
                       for i in range(self.nslots))

        def _give_up():
            if buf[_PH_RCLOSED_OFF] != 0:
                return RingClosed("slot pool reader closed")
            return give_up() if give_up is not None else None

        while True:
            for i in range(self.nslots):
                if buf[_PH_STATES_OFF + i] == 0:
                    buf[_PH_STATES_OFF + i] = 1
                    return i
            if not _doorbell_wait(_any_free, deadline=deadline,
                                  give_up=_give_up):
                raise TimeoutError(
                    "slot pool exhausted (all slots leased by the "
                    "consumer — long-retained decoded messages must be "
                    "copied, see courier/README.md)")

    def write_frames_at(self, index: int, frames) -> None:
        off = self._data_off(index)
        ser.write_framed_into(
            memoryview(self._buf)[off:off + self.slot_size], frames)

    def abandon(self, index: int) -> None:
        """Roll back an acquire whose control-ring reference was never
        emitted (the reader cannot have seen the slot)."""
        self._buf[_PH_STATES_OFF + index] = 0

    # -- reader side ---------------------------------------------------------
    def view(self, index: int, total: int) -> memoryview:
        """Writable view of one message in place (writable so the decode
        can pin the lease — see ``serialization.loads_owned``)."""
        off = self._data_off(index)
        return memoryview(self._buf)[off:off + total]

    def lease(self, index: int) -> SlotLease:
        with self._lock:
            self._outstanding += 1
        return SlotLease(self, index)

    def consume_copy(self, index: int, total: int):
        """PR-2 style copy-out receive: copy the message into fresh
        memory and free the slot immediately (the A/B baseline arm)."""
        data = ser.read_copy(self._buf, self._data_off(index), total)
        self._buf[_PH_STATES_OFF + index] = 0
        return data

    def close_read(self) -> None:
        with contextlib.suppress(Exception):
            self._buf[_PH_RCLOSED_OFF] = 1

    def _release_slot(self, index: int) -> None:
        with self._lock:
            if self._buf is not None:
                with contextlib.suppress(Exception):
                    self._buf[_PH_STATES_OFF + index] = 0
            self._outstanding -= 1
            if self._close_deferred and self._outstanding <= 0:
                self._close_now()

    def _close_now(self) -> None:
        self._close_deferred = False
        self._buf = None
        shm = self._shm
        try:
            shm.close()
        except BufferError:
            # Decoded views are still exported (dealloc ordering runs the
            # lease's __del__ before the view dies, or the caller kept a
            # raw buffer): the mmap must outlive them, and dies with the
            # last view. Disarm the handle — close() bailed before the
            # fd, and SharedMemory.__del__ would re-raise noisily at GC.
            shm._mmap = None  # noqa: SLF001
            if shm._fd >= 0:  # noqa: SLF001
                with contextlib.suppress(OSError):
                    os.close(shm._fd)  # noqa: SLF001
                shm._fd = -1  # noqa: SLF001
        except Exception:  # pragma: no cover - best-effort teardown
            pass

    def release(self, unlink: bool = False) -> None:
        """Unlink the name now (if asked); drop the mapping when the last
        outstanding lease is released. Idempotent."""
        with self._lock:
            if self._released:
                return
            self._released = True
            if unlink:
                _unlink_quiet(self._name)
            if self._outstanding > 0:
                self._close_deferred = True
            else:
                self._close_now()


# ---- one direction: control ring + lazy slot pool ----------------------------

class Chan:
    """One direction of a connection.

    Small messages gather straight into the control ring. Larger ones go
    through the direction's *slot pool* (see :class:`SlotPool`) — created
    lazily by the writer, reused for the connection's lifetime, regrown
    under a fresh versioned name when a bigger message arrives. A tiny
    ``_REF_MAGIC`` reference (segment name + slot index + length) enters
    the control ring; the reader attaches the named pool (cached) and
    decodes the slot **in place** — the slot frees when the decoded
    message's lease dies, so pipelined large messages use distinct slots
    concurrently. ``zero_copy=False`` selects the copy-out receive
    instead (one full copy per message, slot freed immediately): the A/B
    baseline arm in benchmarks/rpc_overhead.py. The per-direction send
    lock keeps slot fills and control records in lockstep order.
    """

    def __init__(self, ctrl: Ring, bulk_name: str, writer: bool,
                 zero_copy: bool = True):
        self._ctrl = ctrl
        self._bulk_name = bulk_name
        self._writer = writer
        self._zero_copy = zero_copy
        self._pool: Optional[SlotPool] = None
        self._pool_version = 0
        self._retired: list[SlotPool] = []
        self._pools_attached: dict[str, SlotPool] = {}
        self._lock = threading.Lock()
        # Telemetry counters (plain ints: GIL-atomic +=, read by stats()).
        self.bytes_out = 0
        self.bytes_in = 0
        self.serialize_us = 0
        self.pool_grows = 0

    # -- writer side ---------------------------------------------------------
    def _new_pool(self, slot_size: int) -> SlotPool:
        self.pool_grows += 1
        if self._pool is not None:
            # The replaced pool may still hold in-flight messages (refs
            # in the ring, leases on the consumer); park it and unlink
            # once every slot has been released.
            self._retired.append(self._pool)
        self._pool_version += 1
        self._pool = SlotPool.create(
            f"{self._bulk_name}v{self._pool_version}", slot_size=slot_size)
        return self._pool

    def _writer_pool(self, total: int) -> SlotPool:
        if self._pool is None or self._pool.slot_size < total:
            self._new_pool(int(total * SLOT_HEADROOM))
        self._reap_retired()
        return self._pool

    def _reap_retired(self) -> None:
        keep = []
        for pool in self._retired:
            if pool.all_free:
                pool.release(unlink=True)
            else:
                keep.append(pool)
        self._retired = keep

    def send(self, kind: int, req_id: int, obj: Any,
             timeout: Optional[float] = None, give_up=None) -> None:
        t0 = time.perf_counter()
        frames = ser.encode_frames(obj)
        total = ser.framed_size(frames)
        self.serialize_us += int((time.perf_counter() - t0) * 1e6)
        self.bytes_out += total
        with self._lock:
            if total <= SPILL_THRESHOLD:
                self._ctrl.write(kind, req_id, ser.framed_chunks(frames),
                                 timeout=timeout, give_up=give_up)
                return
            pool = self._writer_pool(total)
            try:
                grace = _POOL_GROW_GRACE_S if timeout is None \
                    else min(timeout, _POOL_GROW_GRACE_S)
                index = pool.acquire(timeout=grace, give_up=give_up)
            except TimeoutError:
                # The consumer leases every slot (e.g. more pipelined
                # results alive than SLOT_COUNT). Expand with a fresh
                # pool instead of deadlocking: the stalled pool drains as
                # results are dropped and is then reaped, so memory
                # tracks actual concurrent retention. The grace keeps
                # soft backpressure against runaway producers.
                pool = self._new_pool(pool.slot_size)
                index = pool.acquire(timeout=timeout, give_up=give_up)
            try:
                pool.write_frames_at(index, frames)
                name_b = pool.name.encode()
                ref = (_REF_MAGIC + _REF_NAME.pack(len(name_b)) + name_b
                       + _REF_TAIL.pack(index, total))
                self._ctrl.write(kind, req_id, [ref], timeout=timeout,
                                 give_up=give_up)
            except BaseException:
                # The reference never entered the ring: return the slot
                # so later sends don't wait on a message nobody consumes.
                pool.abandon(index)
                raise

    # -- reader side ---------------------------------------------------------
    def recv(self, timeout: Optional[float] = None, give_up=None
             ) -> Optional[tuple[int, int, Any]]:
        """Pop and decode one message. A payload that fails to decode
        (e.g. a class importable only on the peer) comes back as a
        :class:`DecodeFailure` so the request id is not lost — the caller
        can still correlate an error reply."""
        rec = self._ctrl.read(timeout=timeout, give_up=give_up)
        if rec is None:
            return None
        kind, req_id, body = rec
        try:
            obj = self._decode(body)
        except (RingClosed, KeyboardInterrupt, SystemExit):
            raise  # interrupts reach the driving caller, not a reply
        except BaseException as exc:  # noqa: BLE001
            obj = DecodeFailure(exc)
        return kind, req_id, obj

    def _decode(self, body) -> Any:
        # ``body`` is bytes or a memoryview; compare/parse through
        # memoryview slices — no intermediate ``bytes`` materialization.
        mv = memoryview(body)
        if mv.nbytes >= 2 and mv[:2] == _REF_MAGIC:
            (name_len,) = _REF_NAME.unpack_from(mv, 2)
            name = str(mv[4:4 + name_len], "ascii")
            index, total = _REF_TAIL.unpack_from(mv, 4 + name_len)
            self.bytes_in += total
            pool = self._pools_attached.get(name)
            if pool is None:
                pool = SlotPool.attach(name)
                # A new pool name means the writer regrew or expanded:
                # evict drained older attachments so superseded multi-MiB
                # mappings don't pin memory for the connection's
                # lifetime. all_free is a safe eviction test — any
                # in-flight message (published or not) holds its slot's
                # state byte at 1 until the consumer releases the lease;
                # an evicted-but-still-current pool just re-attaches by
                # name on its next reference.
                for old_name, old in list(self._pools_attached.items()):
                    if old.all_free:
                        old.release()
                        del self._pools_attached[old_name]
                self._pools_attached[name] = pool
            # The slot was filled and published before its control-ring
            # reference, so the message is already there.
            if not self._zero_copy:
                return ser.loads(pool.consume_copy(index, total))
            return ser.loads_owned(pool.view(index, total),
                                   pool.lease(index))
        self.bytes_in += mv.nbytes
        return ser.loads(body)

    # -- lifecycle -----------------------------------------------------------
    def close_write(self) -> None:
        with contextlib.suppress(Exception):
            self._ctrl.close_write()

    def close_read(self) -> None:
        with contextlib.suppress(Exception):
            self._ctrl.close_read()
        # Snapshot: the reply-driver thread may attach/evict concurrently.
        for pool in list(self._pools_attached.values()):
            pool.close_read()  # unblock a writer waiting on a leased slot

    @property
    def ctrl(self) -> Ring:
        return self._ctrl

    def release(self, unlink: bool = False) -> None:
        self._ctrl.release(unlink=unlink)
        if self._pool is not None:
            self._pool.release(unlink=True)  # writer owns the pool name
            self._pool = None
        for pool in self._retired:
            pool.release(unlink=True)
        self._retired = []
        for pool in list(self._pools_attached.values()):
            pool.release()  # mapping lives on under outstanding leases
        self._pools_attached.clear()


def _sweep_segments(prefix: str) -> None:
    """Best-effort unlink of leftover segments (crashed peer / unread
    spills). POSIX shm appears under /dev/shm on Linux."""
    for path in glob.glob(f"/dev/shm/{prefix}*"):
        with contextlib.suppress(Exception):
            _unlink_quiet(os.path.basename(path))


# ---- rendezvous --------------------------------------------------------------

def _root_dir() -> str:
    return os.path.join(tempfile.gettempdir(), "courier-shm")


def rendezvous_dir(name: str) -> str:
    return os.path.join(_root_dir(), name)


def probe(name: str) -> str:
    """Listener state: ``"ready"`` | ``"stale"`` (dead pid / wrong host /
    unreadable meta) | ``"absent"``."""
    meta_path = os.path.join(rendezvous_dir(name), "listener.json")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except FileNotFoundError:
        return "absent"
    except Exception:
        return "stale"
    if meta.get("host") != socket.gethostname():
        return "stale"
    pid = meta.get("pid")
    if not isinstance(pid, int) or not _pid_alive(pid):
        return "stale"
    return "ready"


def cleanup(name: str) -> None:
    """Remove a service's rendezvous directory and leftover segments —
    used by launchers tearing down hard-killed nodes."""
    d = rendezvous_dir(name)
    with contextlib.suppress(Exception):
        for fn in os.listdir(d):
            with contextlib.suppress(OSError):
                os.unlink(os.path.join(d, fn))
        os.rmdir(d)


# ---- server side -------------------------------------------------------------

class _ServerConn:
    """One accepted client: a reader thread draining the request channel
    and a reply channel shared by the handler pool."""

    def __init__(self, listener: "ShmListener", conn_id: str,
                 req: Ring, rep: Ring, client_pid: int,
                 zero_copy: bool = True):
        self._listener = listener
        self._conn_id = conn_id
        self._in = Chan(req, bulk_name=f"{conn_id}qb", writer=False,
                        zero_copy=zero_copy)
        self._out = Chan(rep, bulk_name=f"{conn_id}rb", writer=True)
        self._client_pid = client_pid
        self._thread = threading.Thread(
            target=self._serve, name=f"courier-shm-conn/{conn_id}",
            daemon=True)

    def start(self) -> None:
        self._out.ctrl.write(KIND_HELLO, 0, [b""])
        self._thread.start()

    def _client_gone(self) -> Optional[BaseException]:
        # Wakes reply writers blocked on a full ring whose client was
        # SIGKILLed (a dead client never sets its reader-closed flag).
        if not _pid_alive(self._client_pid):
            return RingClosed("client process died")
        return None

    def _reply(self, kind: int, req_id: int, obj: Any) -> None:
        try:
            self._out.send(kind, req_id, obj, give_up=self._client_gone)
        except RingClosed:
            pass  # client left; nothing to deliver the reply to
        except Exception:
            # Unpicklable result/exception: degrade per-status, exactly
            # like the gRPC path's encode_reply_error fallbacks.
            with contextlib.suppress(RingClosed):
                self._out.send(kind, req_id, _degrade(kind, obj),
                               give_up=self._client_gone)

    def _run_call(self, req_id: int, call: tuple) -> None:
        lst = self._listener
        try:
            # handler_init inside the try: its failure must become an
            # error reply, not a silently-dropped pool future that leaves
            # the client waiting forever.
            if lst.handler_init is not None:
                lst.handler_init()
            method, args, kwargs = call
            status = ser.make_ok_status(lst.invoke(method, args, kwargs))
        except BaseException as exc:  # noqa: BLE001 - ship any failure back
            status = ser.make_error_status(exc)
        self._reply(KIND_REPLY, req_id, status)

    def _run_batch(self, req_id: int, calls: list) -> None:
        lst = self._listener
        try:
            if lst.handler_init is not None:
                lst.handler_init()
        except BaseException as exc:  # noqa: BLE001 - whole-batch failure
            self._reply(KIND_REPLY, req_id, ser.make_error_status(exc))
            return
        statuses = []
        for method, args, kwargs in calls:
            # Per-call isolation, statuses in request order (same contract
            # as /courier/BatchCall).
            try:
                statuses.append(
                    ser.make_ok_status(lst.invoke(method, args, kwargs)))
            except BaseException as exc:  # noqa: BLE001
                statuses.append(ser.make_error_status(exc))
        self._reply(KIND_BATCH_REPLY, req_id, statuses)

    def _serve(self) -> None:
        try:
            while not self._listener.stopped:
                try:
                    # Decode happens here (slot consumption must follow
                    # control-ring order); only the invoke may run pooled.
                    rec = self._in.recv(timeout=0.2)
                except RingClosed:
                    return
                if rec is None:
                    if not _pid_alive(self._client_pid):
                        return  # client died without a CLOSE
                    continue
                kind, req_id, obj = rec
                rec = None
                if kind == KIND_CLOSE:
                    return
                if isinstance(obj, DecodeFailure):
                    self._reply(KIND_REPLY, req_id,
                                ser.make_error_status(obj.exc))
                    obj = None
                    continue
                if kind == KIND_CALL:
                    runner = self._run_call
                elif kind == KIND_BATCH:
                    runner = self._run_batch
                else:
                    obj = None
                    continue
                # A lone request runs inline: on small hosts a pool
                # hand-off costs a wake AND leaves this thread spinning
                # next to the worker. A client with pipelined backlog
                # keeps pool concurrency (its calls must not serialize
                # behind one long handler). Caveat: a handler that blocks
                # until a *later* request from the same client arrives
                # can stall its own connection — don't write services
                # like that (other clients' connections are unaffected).
                if self._in.ctrl.has_backlog():
                    try:
                        self._listener.pool.submit(runner, req_id, obj)
                    except RuntimeError:
                        return  # listener stopped the pool mid-accept
                else:
                    runner(req_id, obj)
                # Drop this thread's reference before blocking in recv
                # again: a zero-copy request pins its pool slot through
                # the decoded object's lease, which frees when the last
                # reference (here, or the handler's locals) dies.
                obj = None
        finally:
            self._out.close_write()
            self._in.close_read()
            self._in.release()
            self._out.release()
            _sweep_segments(f"{self._conn_id}")
            self._listener.forget(self)


def _degrade(kind: int, obj: Any) -> Any:
    """Build a picklable stand-in for a reply that failed to encode."""
    def one(status):
        try:
            ser.encode_frames(status)
            return status
        except Exception:
            if status[0] == "ok":
                return ("err", ser.RemoteError(
                    f"result of type {type(status[1]).__name__} is not "
                    "serializable"), "")
            return ("err", ser.RemoteError(repr(status[1])), status[2])
    if kind == KIND_BATCH_REPLY:
        return [one(s) for s in obj]
    return one(obj)


class ShmListener:
    """Accepts shm connections for one service name, alongside whatever
    other transports the server runs. ``invoke`` is the server's dispatch
    (method, args, kwargs) -> value; ``handler_init`` runs at the top of
    every request on the handling thread (same contract as CourierServer).
    """

    def __init__(self, name: str, invoke: Callable[[str, tuple, dict], Any],
                 handler_init: Optional[Callable[[], None]] = None,
                 max_workers: int = 16):
        if not supported():  # pragma: no cover - POSIX-only guard
            raise ShmConnectError("shm transport requires POSIX")
        self.name = name
        self.invoke = invoke
        self.handler_init = handler_init
        self.stopped = False
        self.pool = futures.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="courier-shm-srv")
        self._dir = rendezvous_dir(name)
        self._conns: list[_ServerConn] = []
        self._conns_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None
        os.makedirs(self._dir, exist_ok=True)
        meta = {"host": socket.gethostname(), "pid": os.getpid(),
                "version": 1}
        tmp = os.path.join(self._dir, f".meta.{os.getpid()}.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(self._dir, "listener.json"))

    @property
    def endpoint(self) -> str:
        return f"shm://{self.name}"

    def start(self) -> None:
        if self._accept_thread is not None:
            return
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"courier-shm-accept/{self.name}",
            daemon=True)
        self._accept_thread.start()

    def _accept_one(self, path: str) -> None:
        try:
            with open(path) as f:
                req = json.load(f)
            os.unlink(path)
            conn = _ServerConn(self, req["conn"],
                               req=Ring.attach(req["req"]),
                               rep=Ring.attach(req["rep"]),
                               client_pid=int(req["pid"]),
                               zero_copy=bool(req.get("zc", True)))
        except Exception:  # malformed/raced connect file: drop it
            with contextlib.suppress(OSError):
                os.unlink(path)
            return
        with self._conns_lock:
            self._conns.append(conn)
        conn.start()

    def _accept_loop(self) -> None:
        while not self.stopped:
            try:
                pending = sorted(
                    fn for fn in os.listdir(self._dir)
                    if fn.endswith(".connect"))
            except FileNotFoundError:
                return  # rendezvous dir removed under us: stop accepting
            for fn in pending:
                self._accept_one(os.path.join(self._dir, fn))
            time.sleep(_POLL_ACCEPT_S)

    def forget(self, conn: _ServerConn) -> None:
        with self._conns_lock:
            if conn in self._conns:
                self._conns.remove(conn)

    def stop(self) -> None:
        if self.stopped:
            return
        self.stopped = True
        cleanup(self.name)  # unadvertise first: no new connects
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            # Wake blocked clients; the conn thread may be releasing the
            # ring concurrently, which is fine — the client also watches
            # our pid.
            conn._out.close_write()  # noqa: SLF001
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
        self.pool.shutdown(wait=False)


# ---- client side -------------------------------------------------------------

class ClientConnection:
    """The client half of one shm connection: creates the rings, performs
    the rendezvous handshake, then sends records / receives replies."""

    def __init__(self, name: str, req: Ring, rep: Ring, conn_id: str,
                 server_pid: int, zero_copy: bool = True):
        self.name = name
        self._out = Chan(req, bulk_name=f"{conn_id}qb", writer=True)
        self._in = Chan(rep, bulk_name=f"{conn_id}rb", writer=False,
                        zero_copy=zero_copy)
        self._conn_id = conn_id
        self._server_pid = server_pid
        self._closed = False

    def io_stats(self) -> dict:
        """Wire-level counters for :meth:`ShmTransport.stats` — request
        bytes/serialize time from the outbound channel, reply bytes from
        the inbound one, pool regrows from both."""
        return {
            "bytes_out": self._out.bytes_out,
            "bytes_in": self._in.bytes_in,
            "serialize_us": self._out.serialize_us,
            "pool_grows": self._out.pool_grows + self._in.pool_grows,
        }

    @classmethod
    def connect(cls, name: str, wait: Optional[float] = None,
                zero_copy: bool = True) -> "ClientConnection":
        """``zero_copy=False`` selects the copy-out receive on *both*
        sides of this connection (the server mirrors the flag for its
        request channel) — the PR-2 baseline arm for paired A/B runs."""
        if not supported():
            raise ShmConnectError("shm transport requires POSIX")
        wait = CONNECT_WAIT_S if wait is None else wait
        deadline = time.monotonic() + wait
        # Wait for the listener to advertise (launch is asynchronous); a
        # stale advertisement (dead pid) fails immediately so callers can
        # fall back instead of hanging on a crashed server's leftovers.
        while True:
            state = probe(name)
            if state == "ready":
                break
            if state == "stale":
                raise ShmConnectError(
                    f"shm listener for {name!r} is stale (server crashed?)")
            if time.monotonic() >= deadline:
                raise ShmConnectError(
                    f"shm listener for {name!r} did not come up within "
                    f"{wait:.1f}s")
            time.sleep(0.005)
        d = rendezvous_dir(name)
        try:
            with open(os.path.join(d, "listener.json")) as f:
                server_pid = int(json.load(f)["pid"])
        except (OSError, ValueError, KeyError) as exc:
            # The listener can unadvertise between probe() and this read;
            # surface it as a connect failure so callers fall back.
            raise ShmConnectError(
                f"shm listener for {name!r} disappeared during connect: "
                f"{exc!r}") from exc
        conn_id = f"cur{os.getpid():x}{uuid.uuid4().hex[:8]}"
        req = Ring.create(f"{conn_id}q")
        rep = Ring.create(f"{conn_id}r")
        try:
            spec = {"conn": conn_id, "req": req.name, "rep": rep.name,
                    "pid": os.getpid(), "zc": bool(zero_copy)}
            tmp = os.path.join(d, f".{conn_id}.tmp")
            with open(tmp, "w") as f:
                json.dump(spec, f)
            os.replace(tmp, os.path.join(d, f"{conn_id}.connect"))
            # The HELLO record doubles as the accept ack.
            def _server_died():
                if not _pid_alive(server_pid):
                    return ShmConnectError(
                        f"shm listener for {name!r} died during handshake")
                return None
            rec = rep.read(timeout=ACCEPT_WAIT_S, give_up=_server_died)
            if rec is None or rec[0] != KIND_HELLO:
                raise ShmConnectError(
                    f"shm listener for {name!r} did not accept within "
                    f"{ACCEPT_WAIT_S:.1f}s")
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(os.path.join(d, f"{conn_id}.connect"))
            req.release(unlink=True)
            rep.release(unlink=True)
            raise
        return cls(name, req, rep, conn_id, server_pid,
                   zero_copy=zero_copy)

    # -- data path -----------------------------------------------------------
    def send(self, kind: int, req_id: int, obj: Any,
             timeout: Optional[float] = None) -> None:
        def _server_died():
            if not _pid_alive(self._server_pid):
                return RingClosed("server process died")
            return None
        self._out.send(kind, req_id, obj, timeout=timeout,
                       give_up=_server_died)

    def recv(self, timeout: Optional[float] = None
             ) -> Optional[tuple[int, int, Any]]:
        return self._in.recv(timeout=timeout)

    def peer_alive(self) -> bool:
        return _pid_alive(self._server_pid) and not self._in.ctrl.writer_closed

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with contextlib.suppress(Exception):
            self._out.ctrl.write(KIND_CLOSE, 0, [b""], timeout=0.2)
        self._out.close_write()
        self._in.close_read()

    def release(self) -> None:
        """Unlink the rings (the client created both control rings) plus
        any bulk/one-off segments left under this connection's prefix."""
        self._out.release(unlink=True)
        self._in.release(unlink=True)
        _sweep_segments(self._conn_id)
